//! Execution backends the router can dispatch to.
//!
//! * [`PjrtBackend`] — the production path: AOT HLO artifacts on the PJRT
//!   CPU client (Python never runs here).
//! * [`EngineBackend`] — the blocked multi-threaded CPU engine
//!   ([`crate::gemt::engine`]); the fast native path when PJRT artifacts
//!   are absent. Serves every [`TransformKind`], including `DftSplit` as
//!   four real mode products per mode on the engine's tiled kernels.
//! * [`ShardedEngineBackend`] — the engine behind
//!   [`crate::gemt::shard`]: problems whose dimensions exceed the
//!   configured `max_tile` are block decomposed across engine passes
//!   instead of degrading to the scalar reference.
//! * [`ReferenceBackend`] — exact CPU implementation via `gemt` (used for
//!   response cross-checking and when no artifact matches).
//! * [`SimBackend`] — the TriADA device simulator (returns the same
//!   numerics and additionally accumulates architecture counters).
//!
//! A backend that cannot serve a request on its primary path never degrades
//! silently: every reference fallback is recorded in a [`FallbackNotice`]
//! and logged once per distinct reason.

use std::sync::Mutex;

use crate::gemt::{self, CoeffSet};
use crate::runtime::{Direction, PjrtHandle};
use crate::sim::{self, Counters, SimConfig};
use crate::tensor::Tensor3;
use crate::transforms::TransformKind;

/// A way to execute one transform request.
pub trait Backend: Send + Sync {
    /// Stable identifier shown in CLI output and metrics.
    fn name(&self) -> &'static str;
    /// Execute one transform request (one tensor for real kinds, an
    /// (re, im) pair for [`TransformKind::DftSplit`]).
    fn execute(
        &self,
        kind: TransformKind,
        direction: Direction,
        inputs: &[Tensor3<f32>],
    ) -> anyhow::Result<Vec<Tensor3<f32>>>;
}

// ---------------------------------------------------------------------------

/// Warn-once tracker for backend degradation: records every distinct
/// fallback reason and logs each to stderr exactly once, so a serving path
/// quietly running on the scalar reference is visible in the logs without
/// flooding them per request.
#[derive(Debug, Default)]
pub struct FallbackNotice {
    reasons: Mutex<Vec<String>>,
}

impl FallbackNotice {
    /// Most distinct reasons kept and logged. Callers like the PJRT miss
    /// path embed per-request detail in the reason text, so without a cap a
    /// long-running server would grow the list (and re-warn) without bound;
    /// past the cap a single suppression notice is recorded instead.
    const MAX_REASONS: usize = 32;

    /// Record a fallback; logs the reason the first time it is seen.
    pub fn record(&self, backend: &str, reason: &str) {
        let mut seen = self.reasons.lock().unwrap();
        if seen.iter().any(|r| r == reason) {
            return;
        }
        if seen.len() >= Self::MAX_REASONS {
            if seen.len() == Self::MAX_REASONS {
                eprintln!("warning: backend {backend}: further fallback reasons suppressed");
                seen.push("(further fallback reasons suppressed)".to_string());
            }
            return;
        }
        eprintln!("warning: backend {backend}: {reason}; serving via cpu reference");
        seen.push(reason.to_string());
    }

    /// Every distinct reason recorded so far (empty = no degradation).
    pub fn reasons(&self) -> Vec<String> {
        self.reasons.lock().unwrap().clone()
    }
}

// ---------------------------------------------------------------------------

/// Exact CPU reference (f64 internally).
pub struct ReferenceBackend;

/// Shared helper: run a request through the f64 CPU reference.
pub fn reference_execute(
    kind: TransformKind,
    direction: Direction,
    inputs: &[Tensor3<f32>],
) -> anyhow::Result<Vec<Tensor3<f32>>> {
    let inverse = direction == Direction::Inverse;
    match kind {
        TransformKind::DftSplit => {
            anyhow::ensure!(inputs.len() == 2, "dft-split expects (re, im)");
            let re = inputs[0].to_f64();
            let im = inputs[1].to_f64();
            let (or, oi) = gemt::split::dft3d_split(&re, &im, inverse);
            Ok(vec![or.to_f32(), oi.to_f32()])
        }
        real => {
            anyhow::ensure!(inputs.len() == 1, "{} expects one tensor", real.name());
            let x = inputs[0].to_f64();
            let y = if inverse {
                gemt::dxt3d_inverse(&x, real)
            } else {
                gemt::dxt3d_forward(&x, real)
            };
            Ok(vec![y.to_f32()])
        }
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "cpu-reference"
    }

    fn execute(
        &self,
        kind: TransformKind,
        direction: Direction,
        inputs: &[Tensor3<f32>],
    ) -> anyhow::Result<Vec<Tensor3<f32>>> {
        reference_execute(kind, direction, inputs)
    }
}

// ---------------------------------------------------------------------------

/// Shared by the engine-family backends: run the split complex DFT as four
/// real mode products per mode on the tiled engine kernels.
fn engine_dft_split(
    sharder: &gemt::Sharder,
    direction: Direction,
    inputs: &[Tensor3<f32>],
) -> anyhow::Result<Vec<Tensor3<f32>>> {
    anyhow::ensure!(inputs.len() == 2, "dft-split expects (re, im)");
    let re = inputs[0].to_f64();
    let im = inputs[1].to_f64();
    let (or, oi) = sharder.dft3d_split(&re, &im, direction == Direction::Inverse);
    Ok(vec![or.to_f32(), oi.to_f32()])
}

/// The blocked multi-threaded 3D-GEMT engine as a backend (f64 internally,
/// like the reference — same numerics, parallel hot path). `DftSplit`
/// requests run as four real mode products per mode on the engine's tiled
/// kernels — no scalar fallback.
pub struct EngineBackend {
    engine: gemt::engine::Engine,
    sharder: gemt::Sharder,
}

impl EngineBackend {
    /// Build over an engine configuration (`DftSplit` mode products reuse
    /// the same threads/block knobs with the default tile bound).
    pub fn new(config: gemt::engine::EngineConfig) -> EngineBackend {
        let shard = gemt::ShardConfig { engine: config, ..gemt::ShardConfig::default() };
        EngineBackend {
            engine: gemt::engine::Engine::new(config),
            sharder: gemt::Sharder::new(shard),
        }
    }

    /// The engine this backend executes with.
    pub fn engine(&self) -> &gemt::engine::Engine {
        &self.engine
    }
}

impl Backend for EngineBackend {
    fn name(&self) -> &'static str {
        "engine"
    }

    fn execute(
        &self,
        kind: TransformKind,
        direction: Direction,
        inputs: &[Tensor3<f32>],
    ) -> anyhow::Result<Vec<Tensor3<f32>>> {
        match kind {
            TransformKind::DftSplit => engine_dft_split(&self.sharder, direction, inputs),
            real => {
                anyhow::ensure!(inputs.len() == 1, "{} expects one tensor", real.name());
                let x = inputs[0].to_f64();
                let y = match direction {
                    Direction::Forward => self.engine.dxt3d_forward(&x, real),
                    Direction::Inverse => self.engine.dxt3d_inverse(&x, real),
                };
                Ok(vec![y.to_f32()])
            }
        }
    }
}

// ---------------------------------------------------------------------------

/// The sharding layer ([`crate::gemt::shard`]) as a backend: requests whose
/// dimensions fit `max_tile` run one fused engine pass; oversized or
/// rectangular requests are block decomposed across engine tile passes —
/// bit-identical to the scalar reference either way, so arbitrarily large
/// problems stay on the parallel path.
pub struct ShardedEngineBackend {
    sharder: gemt::Sharder,
}

impl ShardedEngineBackend {
    /// Build over sharding knobs (`[engine] threads / block / max_tile`).
    pub fn new(config: gemt::ShardConfig) -> ShardedEngineBackend {
        ShardedEngineBackend { sharder: gemt::Sharder::new(config) }
    }

    /// The sharder this backend executes with.
    pub fn sharder(&self) -> &gemt::Sharder {
        &self.sharder
    }
}

impl Backend for ShardedEngineBackend {
    fn name(&self) -> &'static str {
        "sharded-engine"
    }

    fn execute(
        &self,
        kind: TransformKind,
        direction: Direction,
        inputs: &[Tensor3<f32>],
    ) -> anyhow::Result<Vec<Tensor3<f32>>> {
        match kind {
            TransformKind::DftSplit => engine_dft_split(&self.sharder, direction, inputs),
            real => {
                anyhow::ensure!(inputs.len() == 1, "{} expects one tensor", real.name());
                let x = inputs[0].to_f64();
                let y = match direction {
                    Direction::Forward => self.sharder.dxt3d_forward(&x, real),
                    Direction::Inverse => self.sharder.dxt3d_inverse(&x, real),
                };
                Ok(vec![y.to_f32()])
            }
        }
    }
}

// ---------------------------------------------------------------------------

/// The TriADA device simulator as a backend; accumulates counters across
/// requests (read them with [`SimBackend::counters`]).
pub struct SimBackend {
    config: SimConfig,
    counters: Mutex<Counters>,
    fallbacks: FallbackNotice,
}

impl SimBackend {
    /// Build over a device configuration.
    pub fn new(config: SimConfig) -> SimBackend {
        SimBackend {
            config,
            counters: Mutex::new(Counters::default()),
            fallbacks: FallbackNotice::default(),
        }
    }

    /// Accumulated architecture counters across every request served.
    pub fn counters(&self) -> Counters {
        self.counters.lock().unwrap().clone()
    }

    /// Reference-fallback reasons recorded so far (empty = every request
    /// ran on the device model).
    pub fn fallback_reasons(&self) -> Vec<String> {
        self.fallbacks.reasons()
    }

    fn run_real(
        &self,
        x: &Tensor3<f64>,
        kind: TransformKind,
        direction: Direction,
    ) -> Tensor3<f64> {
        let (n1, n2, n3) = x.shape();
        let cs = match direction {
            Direction::Forward => CoeffSet::forward(kind, n1, n2, n3),
            Direction::Inverse => CoeffSet::inverse(kind, n1, n2, n3),
        };
        let out = sim::simulate(x, &cs, &self.config);
        self.counters.lock().unwrap().merge(&out.counters);
        out.result
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "triada-sim"
    }

    fn execute(
        &self,
        kind: TransformKind,
        direction: Direction,
        inputs: &[Tensor3<f32>],
    ) -> anyhow::Result<Vec<Tensor3<f32>>> {
        match kind {
            TransformKind::DftSplit => {
                // The device model streams one real coefficient matrix per
                // mode and cannot yet carry the split (cos, −sin) pair, so
                // this backend serves DftSplit via the reference — loudly,
                // once, instead of degrading silently.
                anyhow::ensure!(inputs.len() == 2, "dft-split expects (re, im)");
                self.fallbacks.record(
                    self.name(),
                    "device model cannot stream split complex coefficients (dft-split)",
                );
                reference_execute(kind, direction, inputs)
            }
            real => {
                anyhow::ensure!(inputs.len() == 1, "{} expects one tensor", real.name());
                let y = self.run_real(&inputs[0].to_f64(), real, direction);
                Ok(vec![y.to_f32()])
            }
        }
    }
}

// ---------------------------------------------------------------------------

/// PJRT artifact backend — talks to the [`crate::runtime::PjrtService`]
/// thread through a handle (the `xla` crate types are not `Send`).
pub struct PjrtBackend {
    handle: PjrtHandle,
    /// Fall back to the CPU reference when no artifact matches (dev mode);
    /// off in production so missing artifacts surface as errors.
    pub fallback_to_reference: bool,
    fallbacks: FallbackNotice,
}

impl PjrtBackend {
    /// Strict mode: a missing artifact is an error.
    pub fn new(handle: PjrtHandle) -> PjrtBackend {
        PjrtBackend { handle, fallback_to_reference: false, fallbacks: FallbackNotice::default() }
    }

    /// Dev mode: a missing artifact degrades to the CPU reference (logged
    /// once per distinct reason).
    pub fn with_fallback(handle: PjrtHandle) -> PjrtBackend {
        PjrtBackend { handle, fallback_to_reference: true, fallbacks: FallbackNotice::default() }
    }

    /// The service handle this backend executes through.
    pub fn handle(&self) -> &PjrtHandle {
        &self.handle
    }

    /// Reference-fallback reasons recorded so far (empty = every request
    /// ran on a compiled artifact).
    pub fn fallback_reasons(&self) -> Vec<String> {
        self.fallbacks.reasons()
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn execute(
        &self,
        kind: TransformKind,
        direction: Direction,
        inputs: &[Tensor3<f32>],
    ) -> anyhow::Result<Vec<Tensor3<f32>>> {
        match self.handle.run(kind, direction, inputs.to_vec()) {
            Ok(out) => Ok(out),
            Err(e) if self.fallback_to_reference => {
                self.fallbacks.record(self.name(), &format!("pjrt miss ({e:#})"));
                reference_execute(kind, direction, inputs)
            }
            Err(e) => Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand32(n1: usize, n2: usize, n3: usize, seed: u64) -> Tensor3<f32> {
        let mut rng = Rng::new(seed);
        Tensor3::random(n1, n2, n3, &mut rng).to_f32()
    }

    #[test]
    fn reference_roundtrip() {
        let x = rand32(3, 4, 5, 140);
        let y = ReferenceBackend
            .execute(TransformKind::Dct2, Direction::Forward, &[x.clone()])
            .unwrap();
        let back = ReferenceBackend
            .execute(TransformKind::Dct2, Direction::Inverse, &y)
            .unwrap();
        assert!(x.to_f64().max_abs_diff(&back[0].to_f64()) < 1e-4);
    }

    #[test]
    fn sim_matches_reference() {
        let x = rand32(4, 4, 4, 141);
        let a = ReferenceBackend
            .execute(TransformKind::Dht, Direction::Forward, &[x.clone()])
            .unwrap();
        let sim = SimBackend::new(SimConfig::esop((8, 8, 8)));
        let b = sim.execute(TransformKind::Dht, Direction::Forward, &[x]).unwrap();
        assert!(a[0].to_f64().max_abs_diff(&b[0].to_f64()) < 1e-5);
        assert!(sim.counters().time_steps > 0);
    }

    #[test]
    fn dft_split_needs_two_inputs() {
        let x = rand32(2, 2, 2, 142);
        assert!(ReferenceBackend
            .execute(TransformKind::DftSplit, Direction::Forward, &[x])
            .is_err());
    }

    #[test]
    fn dft_split_roundtrip() {
        let re = rand32(3, 3, 3, 143);
        let im = rand32(3, 3, 3, 144);
        let f = ReferenceBackend
            .execute(TransformKind::DftSplit, Direction::Forward, &[re.clone(), im.clone()])
            .unwrap();
        let b = ReferenceBackend
            .execute(TransformKind::DftSplit, Direction::Inverse, &f)
            .unwrap();
        assert!(re.to_f64().max_abs_diff(&b[0].to_f64()) < 1e-4);
        assert!(im.to_f64().max_abs_diff(&b[1].to_f64()) < 1e-4);
    }

    #[test]
    fn engine_backend_matches_reference() {
        let x = rand32(5, 4, 6, 146);
        let want = ReferenceBackend
            .execute(TransformKind::Dct2, Direction::Forward, &[x.clone()])
            .unwrap();
        let engine = EngineBackend::new(gemt::engine::EngineConfig::with_threads(2));
        let got = engine
            .execute(TransformKind::Dct2, Direction::Forward, &[x])
            .unwrap();
        // f64 internally on both sides and identical accumulation order per
        // output row: agreement is exact up to the f32 edge conversions.
        assert!(want[0].to_f64().max_abs_diff(&got[0].to_f64()) < 1e-6);
        assert_eq!(engine.name(), "engine");
    }

    #[test]
    fn engine_backend_handles_dft_split_and_inverse() {
        let engine = EngineBackend::new(gemt::engine::EngineConfig::with_threads(2));
        let re = rand32(3, 3, 3, 147);
        let im = rand32(3, 3, 3, 148);
        let f = engine
            .execute(TransformKind::DftSplit, Direction::Forward, &[re.clone(), im.clone()])
            .unwrap();
        let b = engine
            .execute(TransformKind::DftSplit, Direction::Inverse, &f)
            .unwrap();
        assert!(re.to_f64().max_abs_diff(&b[0].to_f64()) < 1e-4);
        assert!(im.to_f64().max_abs_diff(&b[1].to_f64()) < 1e-4);
        let x = rand32(4, 4, 4, 149);
        let y = engine
            .execute(TransformKind::Dht, Direction::Forward, &[x.clone()])
            .unwrap();
        let back = engine.execute(TransformKind::Dht, Direction::Inverse, &y).unwrap();
        assert!(x.to_f64().max_abs_diff(&back[0].to_f64()) < 1e-4);
    }

    #[test]
    fn sim_counters_accumulate_across_jobs() {
        let sim = SimBackend::new(SimConfig::esop((8, 8, 8)));
        let x = rand32(2, 2, 2, 145);
        sim.execute(TransformKind::Dct2, Direction::Forward, &[x.clone()]).unwrap();
        let after_one = sim.counters().time_steps;
        sim.execute(TransformKind::Dct2, Direction::Forward, &[x]).unwrap();
        assert_eq!(sim.counters().time_steps, 2 * after_one);
    }

    #[test]
    fn engine_dft_split_matches_reference_bit_exactly() {
        // The engine no longer degrades DftSplit to the scalar reference —
        // it runs four real mode products per mode on the tiled kernels,
        // which are bit-identical to the scalar ones.
        let engine = EngineBackend::new(gemt::engine::EngineConfig::with_threads(3));
        let re = rand32(4, 5, 3, 150);
        let im = rand32(4, 5, 3, 151);
        let want = ReferenceBackend
            .execute(TransformKind::DftSplit, Direction::Forward, &[re.clone(), im.clone()])
            .unwrap();
        let got = engine
            .execute(TransformKind::DftSplit, Direction::Forward, &[re, im])
            .unwrap();
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.to_f64().max_abs_diff(&g.to_f64()), 0.0);
        }
    }

    #[test]
    fn sharded_backend_serves_oversized_bit_identical() {
        let backend = ShardedEngineBackend::new(gemt::ShardConfig {
            max_tile: 4,
            engine: gemt::engine::EngineConfig::with_threads(2),
        });
        assert_eq!(backend.name(), "sharded-engine");
        let x = rand32(11, 9, 13, 152); // every dim oversized for max_tile=4
        let plan = backend.sharder().plan((11, 9, 13), (11, 9, 13));
        assert!(plan.needs_sharding());
        let want = ReferenceBackend
            .execute(TransformKind::Dht, Direction::Forward, &[x.clone()])
            .unwrap();
        let got = backend.execute(TransformKind::Dht, Direction::Forward, &[x]).unwrap();
        assert_eq!(want[0].to_f64().max_abs_diff(&got[0].to_f64()), 0.0);
    }

    #[test]
    fn fallback_notice_dedups_and_caps() {
        let n = FallbackNotice::default();
        n.record("b", "same reason");
        n.record("b", "same reason");
        assert_eq!(n.reasons().len(), 1);
        // Distinct per-request variants stop accumulating at the cap, with
        // one suppression marker recorded in their place.
        for i in 0..100 {
            n.record("b", &format!("variant {i}"));
        }
        let reasons = n.reasons();
        assert_eq!(reasons.len(), FallbackNotice::MAX_REASONS + 1);
        assert!(reasons.last().unwrap().contains("suppressed"));
    }

    #[test]
    fn sim_dft_split_fallback_warns_once() {
        let sim = SimBackend::new(SimConfig::esop((8, 8, 8)));
        assert!(sim.fallback_reasons().is_empty());
        let re = rand32(3, 3, 3, 153);
        let im = rand32(3, 3, 3, 154);
        sim.execute(TransformKind::DftSplit, Direction::Forward, &[re.clone(), im.clone()])
            .unwrap();
        let reasons = sim.fallback_reasons();
        assert_eq!(reasons.len(), 1, "fallback must be recorded");
        assert!(reasons[0].contains("dft-split"), "reason names the transform: {reasons:?}");
        // A second identical request must not duplicate the notice.
        sim.execute(TransformKind::DftSplit, Direction::Forward, &[re, im]).unwrap();
        assert_eq!(sim.fallback_reasons().len(), 1);
        // ...and real kinds never record one.
        let x = rand32(4, 4, 4, 155);
        sim.execute(TransformKind::Dct2, Direction::Forward, &[x]).unwrap();
        assert_eq!(sim.fallback_reasons().len(), 1);
    }
}
