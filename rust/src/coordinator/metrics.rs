//! Coordinator metrics: latency histograms, throughput, batching gain.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Histogram;

use super::plan::PlanCacheStats;

/// Shared metrics sink (one per coordinator).
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

struct Inner {
    latency: Histogram,
    queue_wait: Histogram,
    completed: u64,
    failed: u64,
    batches: u64,
    batched_jobs: u64,
    rejected: u64,
    canceled: u64,
    deadline_missed: u64,
    retries: u64,
    failovers: u64,
}

/// A point-in-time copy for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    /// Jobs resolved [`crate::util::JobError::Canceled`].
    pub canceled: u64,
    /// Jobs resolved [`crate::util::JobError::DeadlineExceeded`] —
    /// evicted at batch flush or stopped at an execute checkpoint.
    pub deadline_missed: u64,
    /// Transient-error execute attempts that were retried.
    pub retries: u64,
    /// Jobs that exhausted retries and were served by the reference
    /// backend instead.
    pub failovers: u64,
    pub batches: u64,
    /// Mean jobs per batch (executable-reuse factor).
    pub mean_batch_size: f64,
    pub throughput_jobs_per_s: f64,
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    pub latency_p99_s: f64,
    pub latency_mean_s: f64,
    pub queue_wait_p50_s: f64,
    pub uptime_s: f64,
    /// Shared plan-cache counters (filled by
    /// [`super::server::Coordinator::metrics`]; zero for a bare `Metrics`).
    pub plans: PlanCacheStats,
    /// Process-wide compute-pool gauges — queue depth, steals,
    /// park/unpark, task latency ([`crate::pool::PoolStats`]). Filled by
    /// [`super::server::Coordinator::metrics`]; zero for a bare `Metrics`.
    pub pool: crate::pool::PoolStats,
    /// Backend degradation reasons ([`super::backend::FallbackNotice`];
    /// empty = every request ran on the backend's primary path). Filled by
    /// [`super::server::Coordinator::metrics`].
    pub fallback_reasons: Vec<String>,
    /// Microkernel selection and per-kind dispatch counts
    /// ([`crate::gemt::kernels::stats`]). Filled by
    /// [`super::server::Coordinator::metrics`]; zero for a bare `Metrics`.
    pub kernels: crate::gemt::kernels::KernelStats,
    /// Wire front-end counters ([`crate::server::ServerStats`]): HTTP
    /// request/latency/shed-load/disconnect totals. Filled by
    /// [`crate::server::Server::metrics`] and the `/v1/metrics` route;
    /// zero for a coordinator with no server in front of it.
    pub server: crate::server::ServerStats,
    /// Sparsity-routing selection, per-plan density/route decisions, and
    /// nnz/skip counters ([`crate::sparse::stats`]). Filled by
    /// [`super::server::Coordinator::metrics`]; default for a bare
    /// `Metrics`.
    pub sparse: crate::sparse::SparseStats,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            inner: Mutex::new(Inner {
                latency: Histogram::latency(),
                queue_wait: Histogram::latency(),
                completed: 0,
                failed: 0,
                batches: 0,
                batched_jobs: 0,
                rejected: 0,
                canceled: 0,
                deadline_missed: 0,
                retries: 0,
                failovers: 0,
            }),
            started: Instant::now(),
        }
    }

    pub fn record_completion(&self, latency_s: f64, queue_wait_s: f64, ok: bool) {
        let mut g = self.inner.lock().unwrap();
        g.latency.record(latency_s.max(0.0));
        g.queue_wait.record(queue_wait_s.max(0.0));
        if ok {
            g.completed += 1;
        } else {
            g.failed += 1;
        }
    }

    pub fn record_batch(&self, size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batched_jobs += size as u64;
    }

    pub fn record_rejection(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// A job resolved `Canceled` (counted apart from `failed`).
    pub fn record_canceled(&self) {
        self.inner.lock().unwrap().canceled += 1;
    }

    /// A job resolved `DeadlineExceeded` (counted apart from `failed`).
    pub fn record_deadline_missed(&self) {
        self.inner.lock().unwrap().deadline_missed += 1;
    }

    /// An execute attempt failed transiently and will be retried.
    pub fn record_retry(&self) {
        self.inner.lock().unwrap().retries += 1;
    }

    /// A job fell back to the reference backend after exhausting retries.
    pub fn record_failover(&self) {
        self.inner.lock().unwrap().failovers += 1;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let uptime = self.started.elapsed().as_secs_f64().max(1e-9);
        MetricsSnapshot {
            completed: g.completed,
            failed: g.failed,
            rejected: g.rejected,
            canceled: g.canceled,
            deadline_missed: g.deadline_missed,
            retries: g.retries,
            failovers: g.failovers,
            batches: g.batches,
            mean_batch_size: if g.batches == 0 {
                0.0
            } else {
                g.batched_jobs as f64 / g.batches as f64
            },
            throughput_jobs_per_s: g.completed as f64 / uptime,
            latency_p50_s: g.latency.quantile(0.50),
            latency_p95_s: g.latency.quantile(0.95),
            latency_p99_s: g.latency.quantile(0.99),
            latency_mean_s: g.latency.mean(),
            queue_wait_p50_s: g.queue_wait.quantile(0.50),
            uptime_s: uptime,
            plans: PlanCacheStats::default(),
            pool: crate::pool::PoolStats::default(),
            fallback_reasons: Vec::new(),
            kernels: crate::gemt::kernels::KernelStats::default(),
            server: crate::server::ServerStats::default(),
            sparse: crate::sparse::SparseStats::default(),
        }
    }
}

impl MetricsSnapshot {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        use crate::util::human;
        let mut s = format!(
            "jobs={} ok / {} failed / {} rejected | batches={} (mean {:.1} jobs) | thrpt={} | p50={} p95={} p99={}",
            self.completed,
            self.failed,
            self.rejected,
            self.batches,
            self.mean_batch_size,
            human::rate(self.throughput_jobs_per_s),
            human::duration(self.latency_p50_s),
            human::duration(self.latency_p95_s),
            human::duration(self.latency_p99_s),
        );
        if self.canceled + self.deadline_missed + self.retries + self.failovers > 0 {
            s.push_str(&format!(
                " | lifecycle: {} canceled / {} expired / {} retries / {} failovers",
                self.canceled, self.deadline_missed, self.retries, self.failovers
            ));
        }
        if self.plans.hits + self.plans.misses > 0 {
            s.push_str(&format!(
                " | plans={} ({} hits / {} builds)",
                self.plans.entries, self.plans.hits, self.plans.builds
            ));
        }
        if self.pool.executed > 0 {
            s.push_str(&format!(
                " | pool={}w depth={} ({} tasks, {} stolen, wait p~mean {})",
                self.pool.workers,
                self.pool.queue_depth,
                self.pool.executed,
                self.pool.stolen,
                human::duration(self.pool.task_wait_mean_s),
            ));
        }
        if self.kernels.scalar_dispatches + self.kernels.wide_dispatches > 0 {
            s.push_str(&format!(
                " | kernels={}/{} ({} wide / {} scalar dispatches)",
                self.kernels.selected,
                self.kernels.isa,
                self.kernels.wide_dispatches,
                self.kernels.scalar_dispatches,
            ));
        }
        if self.sparse.dense_routes + self.sparse.compressed_routes > 0 {
            s.push_str(&format!(
                " | sparse={} thr={:.2} ({} compressed / {} dense routes, {} nnz / {} skipped)",
                self.sparse.selection,
                self.sparse.threshold,
                self.sparse.compressed_routes,
                self.sparse.dense_routes,
                self.sparse.nnz_processed,
                self.sparse.zeros_skipped,
            ));
        }
        if self.server.requests > 0 {
            s.push_str(&format!(
                " | http: {} reqs ({} ok / {} shed / {} hung up) p99={}",
                self.server.requests,
                self.server.ok,
                self.server.rejected,
                self.server.disconnects,
                human::duration(self.server.request_p99_s),
            ));
        }
        if !self.fallback_reasons.is_empty() {
            s.push_str(&format!(" | DEGRADED ({} reason(s))", self.fallback_reasons.len()));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_completion(0.010, 0.001, true);
        m.record_completion(0.020, 0.002, true);
        m.record_completion(0.5, 0.4, false);
        m.record_batch(3);
        m.record_rejection();
        m.record_canceled();
        m.record_deadline_missed();
        m.record_retry();
        m.record_retry();
        m.record_failover();
        let s = m.snapshot();
        assert_eq!(s.completed, 2);
        assert_eq!(s.failed, 1);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.canceled, 1);
        assert_eq!(s.deadline_missed, 1);
        assert_eq!(s.retries, 2);
        assert_eq!(s.failovers, 1);
        assert_eq!(s.batches, 1);
        assert!(s.summary().contains("1 canceled / 1 expired / 2 retries / 1 failovers"));
        assert!((s.mean_batch_size - 3.0).abs() < 1e-12);
        assert!(s.latency_p50_s > 0.0);
        assert!(s.latency_p99_s >= s.latency_p50_s);
        assert!(s.summary().contains("jobs=2 ok"));
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.mean_batch_size, 0.0);
        assert_eq!(s.plans, PlanCacheStats::default());
        assert_eq!(s.pool, crate::pool::PoolStats::default());
        assert!(s.fallback_reasons.is_empty());
        assert_eq!(s.kernels, crate::gemt::kernels::KernelStats::default());
        assert_eq!(s.server, crate::server::ServerStats::default());
        assert_eq!(s.sparse, crate::sparse::SparseStats::default());
    }

    #[test]
    fn summary_surfaces_plans_and_degradation() {
        let m = Metrics::new();
        m.record_completion(0.010, 0.001, true);
        let mut s = m.snapshot();
        assert!(!s.summary().contains("plans="), "no plan traffic yet");
        assert!(!s.summary().contains("DEGRADED"));
        s.plans = PlanCacheStats { hits: 9, misses: 1, builds: 1, evictions: 0, entries: 1 };
        s.fallback_reasons = vec!["pjrt miss (no artifact)".to_string()];
        let line = s.summary();
        assert!(line.contains("plans=1 (9 hits / 1 builds)"), "{line}");
        assert!(line.contains("DEGRADED (1 reason(s))"), "{line}");
        // Pool gauges appear once tasks have executed.
        assert!(!line.contains("pool="), "no pool traffic yet: {line}");
        s.pool = crate::pool::PoolStats {
            workers: 4,
            executed: 12,
            submitted: 12,
            ..Default::default()
        };
        let line = s.summary();
        assert!(line.contains("pool=4w"), "{line}");
        // Kernel stats appear once any dispatch has been counted.
        assert!(!line.contains("kernels="), "no kernel traffic yet: {line}");
        s.kernels = crate::gemt::kernels::KernelStats {
            selected: "wide",
            isa: "avx2",
            scalar_dispatches: 2,
            wide_dispatches: 40,
        };
        let line = s.summary();
        assert!(line.contains("kernels=wide/avx2 (40 wide / 2 scalar dispatches)"), "{line}");
        // Wire counters appear once the HTTP front-end has served traffic.
        assert!(!line.contains("http:"), "no http traffic yet: {line}");
        s.server = crate::server::ServerStats {
            connections: 3,
            requests: 10,
            ok: 7,
            rejected: 2,
            disconnects: 1,
            ..Default::default()
        };
        let line = s.summary();
        assert!(line.contains("http: 10 reqs (7 ok / 2 shed / 1 hung up)"), "{line}");
        // Sparse routing appears once any route decision has been made.
        assert!(!line.contains("sparse="), "no sparse traffic yet: {line}");
        s.sparse = crate::sparse::SparseStats {
            selection: "auto",
            threshold: 0.9,
            dense_routes: 3,
            compressed_routes: 5,
            nnz_processed: 100,
            zeros_skipped: 900,
            plans: Vec::new(),
        };
        let line = s.summary();
        assert!(
            line.contains("sparse=auto thr=0.90 (5 compressed / 3 dense routes, 100 nnz / 900 skipped)"),
            "{line}"
        );
    }
}
