//! Dynamic batcher: groups queued jobs by [`crate::coordinator::job::BatchKey`]
//! so every job in a batch executes against the same compiled executable —
//! the L3 reuse that mirrors the device's coefficient-matrix sharing across
//! slices.
//!
//! Policy: a bucket flushes when it reaches `max_batch` jobs or when its
//! oldest job has waited `window`; a periodic sweep flushes stragglers.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::job::{BatchKey, TransformJob};

/// A flushed batch: compatible jobs plus their reply channels (attached by
/// the server; generic here so the batcher is testable standalone).
#[derive(Debug)]
pub struct Batch<J> {
    pub key: BatchKey,
    pub jobs: Vec<J>,
}

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub window: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, window: Duration::from_millis(2) }
    }
}

/// Accumulates jobs into per-key buckets and decides when to flush.
pub struct Batcher<J> {
    policy: BatchPolicy,
    buckets: HashMap<BatchKey, Bucket<J>>,
}

struct Bucket<J> {
    jobs: Vec<J>,
    oldest: Instant,
}

impl<J> Batcher<J> {
    pub fn new(policy: BatchPolicy) -> Batcher<J> {
        Batcher { policy, buckets: HashMap::new() }
    }

    /// Add a job; returns a batch if its bucket is now full.
    pub fn add(&mut self, key: BatchKey, job: J, now: Instant) -> Option<Batch<J>> {
        let bucket = self
            .buckets
            .entry(key)
            .or_insert_with(|| Bucket { jobs: Vec::new(), oldest: now });
        if bucket.jobs.is_empty() {
            bucket.oldest = now;
        }
        bucket.jobs.push(job);
        if bucket.jobs.len() >= self.policy.max_batch {
            let b = self.buckets.remove(&key).unwrap();
            Some(Batch { key, jobs: b.jobs })
        } else {
            None
        }
    }

    /// Flush every bucket whose oldest job has exceeded the window.
    pub fn flush_expired(&mut self, now: Instant) -> Vec<Batch<J>> {
        let expired: Vec<BatchKey> = self
            .buckets
            .iter()
            .filter(|(_, b)| now.duration_since(b.oldest) >= self.policy.window)
            .map(|(k, _)| *k)
            .collect();
        expired
            .into_iter()
            .map(|key| {
                let b = self.buckets.remove(&key).unwrap();
                Batch { key, jobs: b.jobs }
            })
            .collect()
    }

    /// Flush everything (shutdown).
    pub fn flush_all(&mut self) -> Vec<Batch<J>> {
        self.buckets
            .drain()
            .map(|(key, b)| Batch { key, jobs: b.jobs })
            .collect()
    }

    /// Next deadline at which some bucket expires (for the poll timeout).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.buckets
            .values()
            .map(|b| b.oldest + self.policy.window)
            .min()
    }

    pub fn pending(&self) -> usize {
        self.buckets.values().map(|b| b.jobs.len()).sum()
    }
}

/// Helper used by the server: key extraction for real jobs.
pub fn key_of(job: &TransformJob) -> BatchKey {
    job.batch_key()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Direction;
    use crate::transforms::TransformKind;

    fn key(kind: TransformKind) -> BatchKey {
        BatchKey { kind, direction: Direction::Forward, shape: (4, 4, 4) }
    }

    #[test]
    fn flushes_on_max_batch() {
        let mut b: Batcher<u32> = Batcher::new(BatchPolicy { max_batch: 3, window: Duration::from_secs(10) });
        let now = Instant::now();
        assert!(b.add(key(TransformKind::Dct2), 1, now).is_none());
        assert!(b.add(key(TransformKind::Dct2), 2, now).is_none());
        let batch = b.add(key(TransformKind::Dct2), 3, now).unwrap();
        assert_eq!(batch.jobs, vec![1, 2, 3]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn keeps_keys_separate() {
        let mut b: Batcher<u32> = Batcher::new(BatchPolicy { max_batch: 2, window: Duration::from_secs(10) });
        let now = Instant::now();
        b.add(key(TransformKind::Dct2), 1, now);
        b.add(key(TransformKind::Dht), 2, now);
        assert_eq!(b.pending(), 2);
        let batch = b.add(key(TransformKind::Dct2), 3, now).unwrap();
        assert_eq!(batch.key.kind, TransformKind::Dct2);
        assert_eq!(batch.jobs, vec![1, 3]);
    }

    #[test]
    fn flushes_on_window_expiry() {
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy { max_batch: 100, window: Duration::from_millis(5) });
        let t0 = Instant::now();
        b.add(key(TransformKind::Dct2), 1, t0);
        assert!(b.flush_expired(t0).is_empty());
        let later = t0 + Duration::from_millis(6);
        let flushed = b.flush_expired(later);
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].jobs, vec![1]);
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy { max_batch: 100, window: Duration::from_millis(5) });
        assert!(b.next_deadline().is_none());
        let t0 = Instant::now();
        b.add(key(TransformKind::Dct2), 1, t0);
        let d = b.next_deadline().unwrap();
        assert_eq!(d, t0 + Duration::from_millis(5));
    }

    #[test]
    fn flush_all_empties() {
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy { max_batch: 100, window: Duration::from_secs(1) });
        let now = Instant::now();
        b.add(key(TransformKind::Dct2), 1, now);
        b.add(key(TransformKind::Dht), 2, now);
        let all = b.flush_all();
        assert_eq!(all.iter().map(|x| x.jobs.len()).sum::<usize>(), 2);
        assert_eq!(b.pending(), 0);
    }
}
