//! The coordinator itself: submit-side API, batcher thread, batch dispatch
//! onto the process-wide compute pool, and graceful shutdown.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Context;

use super::backend::Backend;
use super::batcher::{BatchPolicy, Batcher};
use super::job::{JobId, JobResult, TransformJob};
use super::metrics::{Metrics, MetricsSnapshot};
use super::plan::{DEFAULT_PLAN_CAPACITY, PlanCache, PlanCacheStats};
use super::queue::{BoundedQueue, PopError};
use super::worker::{BatchDispatcher, Pending};

/// Coordinator knobs (see `config/` for the file form).
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Maximum batches in flight on the compute pool at once (the
    /// dispatcher's admission limit — formerly the OS worker-thread
    /// count; execution itself happens on `[pool] threads` workers).
    pub workers: usize,
    /// Submit-queue capacity — the backpressure bound.
    pub queue_depth: usize,
    pub batch: BatchPolicy,
    /// Capacity of the shared stationary-plan cache (LRU-evicted; file form
    /// `[plan_cache] capacity`, CLI `--plan-cache`).
    pub plan_capacity: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8),
            queue_depth: 256,
            batch: BatchPolicy::default(),
            plan_capacity: DEFAULT_PLAN_CAPACITY,
        }
    }
}

impl CoordinatorConfig {
    /// Build from a parsed [`crate::config::Config`] `[coordinator]` section.
    pub fn from_config(cfg: &crate::config::Config) -> anyhow::Result<CoordinatorConfig> {
        let mut c = CoordinatorConfig::default();
        if let Some(w) = cfg.get_usize("coordinator", "workers")? {
            anyhow::ensure!(w > 0, "coordinator.workers must be positive");
            c.workers = w;
        }
        if let Some(d) = cfg.get_usize("coordinator", "queue_depth")? {
            anyhow::ensure!(d > 0, "coordinator.queue_depth must be positive");
            c.queue_depth = d;
        }
        if let Some(b) = cfg.get_usize("coordinator", "max_batch")? {
            anyhow::ensure!(b > 0, "coordinator.max_batch must be positive");
            c.batch.max_batch = b;
        }
        if let Some(ms) = cfg.get_f64("coordinator", "batch_window_ms")? {
            // Duration::from_secs_f64 panics on negative/NaN/overflowing
            // input; reject those as config errors instead.
            anyhow::ensure!(
                ms.is_finite() && ms >= 0.0,
                "coordinator.batch_window_ms must be finite and non-negative, got {ms}"
            );
            c.batch.window = Duration::from_secs_f64(ms / 1000.0);
        }
        if let Some(p) = cfg.get_usize("plan_cache", "capacity")? {
            anyhow::ensure!(p > 0, "plan_cache.capacity must be positive");
            c.plan_capacity = p;
        }
        Ok(c)
    }
}

/// Handle for a submitted job.
pub struct JobHandle {
    pub id: JobId,
    rx: Receiver<JobResult>,
}

/// Outcome of a timed wait on a [`JobHandle`] — distinguishes "not done
/// yet" from "will never be done" so callers can retry vs. give up.
#[derive(Debug)]
pub enum WaitOutcome {
    /// The job completed (successfully or not — see [`JobResult::outputs`]).
    Ready(JobResult),
    /// The timeout elapsed; the job is still in flight — wait again.
    TimedOut,
    /// The coordinator dropped the job (worker died or shutdown); no result
    /// will ever arrive.
    Disconnected,
}

impl JobHandle {
    /// Block for the result.
    pub fn wait(self) -> anyhow::Result<JobResult> {
        self.rx.recv().context("coordinator dropped the job (shutdown?)")
    }

    /// Block with a timeout, reporting *why* no result was returned.
    pub fn wait_timeout(&self, timeout: Duration) -> WaitOutcome {
        use std::sync::mpsc::RecvTimeoutError;
        match self.rx.recv_timeout(timeout) {
            Ok(res) => WaitOutcome::Ready(res),
            Err(RecvTimeoutError::Timeout) => WaitOutcome::TimedOut,
            Err(RecvTimeoutError::Disconnected) => WaitOutcome::Disconnected,
        }
    }
}

/// The running coordinator.
pub struct Coordinator {
    submit_q: Arc<BoundedQueue<Pending>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    batcher: Option<JoinHandle<()>>,
    dispatcher: Arc<BatchDispatcher>,
    backend: Arc<dyn Backend>,
    plans: Arc<PlanCache>,
}

impl Coordinator {
    /// Start the batcher thread over a backend; flushed batches execute as
    /// compute-pool tasks via a [`BatchDispatcher`] admitting at most
    /// `workers` batches in flight. All batches share one [`PlanCache`],
    /// so every `(kind, direction, shape)` group the batcher forms streams
    /// through a single stationary plan.
    pub fn start(config: CoordinatorConfig, backend: Arc<dyn Backend>) -> Coordinator {
        let submit_q: Arc<BoundedQueue<Pending>> = Arc::new(BoundedQueue::new(config.queue_depth));
        let metrics = Arc::new(Metrics::new());
        let plans = Arc::new(PlanCache::new(config.plan_capacity));
        let dispatcher = Arc::new(BatchDispatcher::new(
            backend.clone(),
            plans.clone(),
            metrics.clone(),
            config.workers.max(1),
        ));

        let batcher = {
            let submit_q = submit_q.clone();
            let dispatcher = dispatcher.clone();
            let policy = config.batch;
            std::thread::Builder::new()
                .name("triada-batcher".into())
                .spawn(move || batcher_loop(submit_q, dispatcher, policy))
                .expect("spawn batcher")
        };

        Coordinator {
            submit_q,
            metrics,
            next_id: AtomicU64::new(1),
            batcher: Some(batcher),
            dispatcher,
            backend,
            plans,
        }
    }

    /// Which backend this coordinator serves with.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Counters of the shared plan cache.
    pub fn plan_stats(&self) -> PlanCacheStats {
        self.plans.stats()
    }

    /// Submit a job, blocking if the queue is full (backpressure).
    pub fn submit(&self, mut job: TransformJob) -> anyhow::Result<JobHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        job.id = id;
        job.submitted_at = Instant::now();
        let (tx, rx) = channel();
        let pending = Pending { job, reply: tx, enqueued_at: Instant::now() };
        self.submit_q
            .push(pending)
            .map_err(|_| anyhow::anyhow!("coordinator is shut down"))?;
        Ok(JobHandle { id, rx })
    }

    /// Non-blocking submit; `None` when the queue is full (load-shed).
    pub fn try_submit(&self, mut job: TransformJob) -> Option<JobHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        job.id = id;
        job.submitted_at = Instant::now();
        let (tx, rx) = channel();
        let pending = Pending { job, reply: tx, enqueued_at: Instant::now() };
        match self.submit_q.try_push(pending) {
            Ok(()) => Some(JobHandle { id, rx }),
            Err(_) => {
                self.metrics.record_rejection();
                None
            }
        }
    }

    /// Submit and wait (convenience).
    pub fn transform(&self, job: TransformJob) -> anyhow::Result<JobResult> {
        self.submit(job)?.wait()
    }

    /// Point-in-time metrics, including plan-cache counters, compute-pool
    /// gauges, and any backend degradation reasons
    /// ([`super::backend::FallbackNotice`]).
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        snap.plans = self.plans.stats();
        snap.pool = crate::pool::global().stats();
        snap.fallback_reasons = self.backend.fallback_reasons();
        snap
    }

    pub fn queue_len(&self) -> usize {
        self.submit_q.len()
    }

    /// Stop intake, join the batcher (which flushes and dispatches every
    /// buffered batch on its way out), then wait for all in-flight batch
    /// tasks to finish on the pool. Idempotent.
    fn stop(&mut self) {
        self.submit_q.close();
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        self.dispatcher.drain();
    }

    /// Graceful shutdown: stop intake, drain every pending batch.
    pub fn shutdown(mut self) {
        self.stop();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Batcher thread body: accumulate → flush on size/window → dispatch as a
/// pool task. Dispatch applies its own in-flight backpressure and never
/// fails, so every accepted job is eventually answered.
fn batcher_loop(
    submit_q: Arc<BoundedQueue<Pending>>,
    dispatcher: Arc<BatchDispatcher>,
    policy: BatchPolicy,
) {
    let mut batcher: Batcher<Pending> = Batcher::new(policy);
    loop {
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match submit_q.pop_timeout(timeout.max(Duration::from_micros(100))) {
            Ok(pending) => {
                let key = pending.job.batch_key();
                if let Some(batch) = batcher.add(key, pending, Instant::now()) {
                    dispatcher.dispatch(batch);
                }
            }
            Err(PopError::Timeout) => {}
            Err(PopError::Closed) => {
                for batch in batcher.flush_all() {
                    dispatcher.dispatch(batch);
                }
                return;
            }
        }
        for batch in batcher.flush_expired(Instant::now()) {
            dispatcher.dispatch(batch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::ReferenceBackend;
    use crate::runtime::Direction;
    use crate::tensor::Tensor3;
    use crate::transforms::TransformKind;
    use crate::util::Rng;

    fn coordinator(workers: usize) -> Coordinator {
        let cfg = CoordinatorConfig {
            workers,
            queue_depth: 64,
            batch: BatchPolicy { max_batch: 4, window: Duration::from_millis(1) },
            ..CoordinatorConfig::default()
        };
        Coordinator::start(cfg, Arc::new(ReferenceBackend))
    }

    fn job(seed: u64) -> TransformJob {
        let mut rng = Rng::new(seed);
        let x = Tensor3::random(4, 5, 6, &mut rng).to_f32();
        TransformJob::new(TransformKind::Dct2, Direction::Forward, vec![x])
    }

    #[test]
    fn single_job_roundtrip() {
        let c = coordinator(2);
        let res = c.transform(job(1)).unwrap();
        let out = res.outputs.unwrap();
        assert_eq!(out[0].shape(), (4, 5, 6));
        assert!(res.latency_s >= 0.0);
        c.shutdown();
    }

    #[test]
    fn many_concurrent_jobs_all_complete() {
        let c = Arc::new(coordinator(4));
        let handles: Vec<_> = (0..40).map(|i| c.submit(job(i)).unwrap()).collect();
        let mut ids = std::collections::HashSet::new();
        for h in handles {
            let r = h.wait().unwrap();
            assert!(r.outputs.is_ok());
            assert!(ids.insert(r.id), "duplicate result id {}", r.id);
        }
        let snap = c.metrics();
        assert_eq!(snap.completed, 40);
        assert_eq!(snap.failed, 0);
        assert!(snap.mean_batch_size >= 1.0);
        Arc::try_unwrap(c).ok().unwrap().shutdown();
    }

    #[test]
    fn batching_groups_compatible_jobs() {
        let c = coordinator(1);
        let handles: Vec<_> = (0..8).map(|i| c.submit(job(i)).unwrap()).collect();
        let mut max_batch = 0;
        for h in handles {
            max_batch = max_batch.max(h.wait().unwrap().batch_size);
        }
        assert!(max_batch >= 2, "no batching observed (max={max_batch})");
        c.shutdown();
    }

    #[test]
    fn invalid_jobs_fail_without_poisoning() {
        let c = coordinator(2);
        let bad = TransformJob::new(TransformKind::Dwht, Direction::Forward, vec![Tensor3::zeros(3, 3, 3)]);
        let r = c.transform(bad).unwrap();
        assert!(r.outputs.is_err());
        // still serving
        let ok = c.transform(job(9)).unwrap();
        assert!(ok.outputs.is_ok());
        c.shutdown();
    }

    #[test]
    fn shutdown_rejects_new_jobs() {
        let c = coordinator(1);
        let q = c.submit_q.clone();
        c.shutdown();
        assert!(q
            .try_push(Pending {
                job: job(1),
                reply: channel().0,
                enqueued_at: Instant::now()
            })
            .is_err());
    }

    #[test]
    fn config_from_file_section() {
        let cfg = crate::config::Config::parse(
            "[coordinator]\nworkers = 3\nqueue_depth = 7\nmax_batch = 5\nbatch_window_ms = 4\n\n[plan_cache]\ncapacity = 9\n",
        )
        .unwrap();
        let c = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(c.workers, 3);
        assert_eq!(c.queue_depth, 7);
        assert_eq!(c.batch.max_batch, 5);
        assert_eq!(c.batch.window, Duration::from_millis(4));
        assert_eq!(c.plan_capacity, 9);
    }

    #[test]
    fn config_rejects_zero_workers() {
        let cfg = crate::config::Config::parse("[coordinator]\nworkers = 0\n").unwrap();
        assert!(CoordinatorConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn config_rejects_zero_plan_capacity_and_defaults_when_absent() {
        let zero = crate::config::Config::parse("[plan_cache]\ncapacity = 0\n").unwrap();
        assert!(CoordinatorConfig::from_config(&zero).is_err());
        let empty = crate::config::Config::parse("").unwrap();
        let c = CoordinatorConfig::from_config(&empty).unwrap();
        assert_eq!(c.plan_capacity, super::DEFAULT_PLAN_CAPACITY);
    }

    #[test]
    fn coordinator_metrics_surface_plan_cache_counters() {
        let c = coordinator(2);
        for i in 0..6 {
            let r = c.transform(job(20 + i)).unwrap();
            assert!(r.outputs.is_ok());
        }
        let snap = c.metrics();
        assert_eq!(snap.plans.builds, 1, "one shape/kind/direction = one plan build");
        assert!(snap.plans.hits + snap.plans.misses >= 1);
        assert_eq!(c.plan_stats().builds, 1);
        assert!(snap.fallback_reasons.is_empty(), "reference never degrades");
        // Batches ran as compute-pool tasks, so the pool gauges are live.
        assert_eq!(snap.pool.workers, crate::pool::global().width());
        assert!(snap.pool.executed >= 1, "batch tasks must show in pool gauges");
        c.shutdown();
    }

    #[test]
    fn config_rejects_negative_or_nonfinite_batch_window() {
        for bad in ["-1", "-0.25", "nan", "inf", "-inf"] {
            let cfg = crate::config::Config::parse(&format!(
                "[coordinator]\nbatch_window_ms = {bad}\n"
            ))
            .unwrap();
            assert!(
                CoordinatorConfig::from_config(&cfg).is_err(),
                "batch_window_ms = {bad} must be rejected"
            );
        }
        // Zero is a legal "flush immediately" window, not a panic.
        let zero = crate::config::Config::parse("[coordinator]\nbatch_window_ms = 0\n").unwrap();
        let c = CoordinatorConfig::from_config(&zero).unwrap();
        assert_eq!(c.batch.window, Duration::ZERO);
    }

    #[test]
    fn wait_timeout_times_out_then_delivers() {
        let c = coordinator(1);
        let h = c.submit(job(11)).unwrap();
        // Eventually the result arrives; every pre-delivery poll must be
        // TimedOut (never Disconnected — the worker pool is healthy).
        let mut delivered = false;
        for _ in 0..2000 {
            match h.wait_timeout(Duration::from_millis(5)) {
                WaitOutcome::Ready(res) => {
                    assert!(res.outputs.is_ok());
                    delivered = true;
                    break;
                }
                WaitOutcome::TimedOut => continue,
                WaitOutcome::Disconnected => panic!("healthy pool must not disconnect"),
            }
        }
        assert!(delivered, "job never completed");
        c.shutdown();
    }
}
