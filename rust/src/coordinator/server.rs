//! The coordinator itself: submit-side admission control, batcher thread,
//! batch dispatch onto the process-wide compute pool, and graceful (or
//! deadline-bounded) shutdown.
//!
//! Every job rides a [`JobContext`]: submit-side deadlines (the config
//! `deadline_ms` default or an explicit context) and a cancel token the
//! caller keeps through its [`JobHandle`]. The batcher evicts
//! already-interrupted jobs at flush time, the dispatcher re-checks before
//! execute, and the engine/shard layers poll between phases and tiles —
//! so canceled or expired work resolves quickly with a typed
//! [`super::job::JobError`] instead of burning compute.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Context;

use super::backend::Backend;
use super::batcher::{BatchPolicy, Batcher};
use super::job::{CancelToken, JobContext, JobId, JobResult, SubmitError, TransformJob};
use super::metrics::{Metrics, MetricsSnapshot};
use super::plan::{DEFAULT_PLAN_CAPACITY, PlanCache, PlanCacheStats};
use super::queue::{BoundedQueue, PopError, PushError};
use super::worker::{evict_interrupted, BatchDispatcher, Pending, RetryPolicy};
use crate::util::WeakCancelToken;

/// Coordinator knobs (see `config/` for the file form).
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Maximum batches in flight on the compute pool at once (the
    /// dispatcher's admission limit — formerly the OS worker-thread
    /// count; execution itself happens on `[pool] threads` workers).
    pub workers: usize,
    /// Submit-queue capacity — the backpressure bound.
    pub queue_depth: usize,
    pub batch: BatchPolicy,
    /// Capacity of the shared stationary-plan cache (LRU-evicted; file form
    /// `[plan_cache] capacity`, CLI `--plan-cache`).
    pub plan_capacity: usize,
    /// Default per-job deadline applied by [`Coordinator::submit`] when the
    /// caller does not bring its own context (`None` = no deadline; file
    /// form `deadline_ms`, 0 = off).
    pub deadline: Option<Duration>,
    /// How long [`Coordinator::submit`] may block on a full queue before
    /// rejecting (`None` = block indefinitely; file form
    /// `submit_timeout_ms`, 0 = block).
    pub submit_timeout: Option<Duration>,
    /// Transient-failure retry/backoff/failover policy (file form
    /// `retry_attempts` / `retry_base_ms` / `retry_cap_ms` /
    /// `retry_failover`).
    pub retry: RetryPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8),
            queue_depth: 256,
            batch: BatchPolicy::default(),
            plan_capacity: DEFAULT_PLAN_CAPACITY,
            deadline: None,
            submit_timeout: None,
            retry: RetryPolicy::default(),
        }
    }
}

impl CoordinatorConfig {
    /// Build from a parsed [`crate::config::Config`] `[coordinator]` section.
    pub fn from_config(cfg: &crate::config::Config) -> anyhow::Result<CoordinatorConfig> {
        let mut c = CoordinatorConfig::default();
        if let Some(w) = cfg.get_usize("coordinator", "workers")? {
            anyhow::ensure!(w > 0, "coordinator.workers must be positive");
            c.workers = w;
        }
        if let Some(d) = cfg.get_usize("coordinator", "queue_depth")? {
            anyhow::ensure!(d > 0, "coordinator.queue_depth must be positive");
            c.queue_depth = d;
        }
        if let Some(b) = cfg.get_usize("coordinator", "max_batch")? {
            anyhow::ensure!(b > 0, "coordinator.max_batch must be positive");
            c.batch.max_batch = b;
        }
        if let Some(ms) = cfg.get_f64("coordinator", "batch_window_ms")? {
            // Duration::from_secs_f64 panics on negative/NaN/overflowing
            // input; reject those as config errors instead.
            anyhow::ensure!(
                ms.is_finite() && ms >= 0.0,
                "coordinator.batch_window_ms must be finite and non-negative, got {ms}"
            );
            c.batch.window = Duration::from_secs_f64(ms / 1000.0);
        }
        if let Some(ms) = cfg.get_f64("coordinator", "deadline_ms")? {
            anyhow::ensure!(
                ms.is_finite() && ms >= 0.0,
                "coordinator.deadline_ms must be finite and non-negative, got {ms}"
            );
            c.deadline = (ms > 0.0).then(|| Duration::from_secs_f64(ms / 1000.0));
        }
        if let Some(ms) = cfg.get_f64("coordinator", "submit_timeout_ms")? {
            anyhow::ensure!(
                ms.is_finite() && ms >= 0.0,
                "coordinator.submit_timeout_ms must be finite and non-negative, got {ms}"
            );
            c.submit_timeout = (ms > 0.0).then(|| Duration::from_secs_f64(ms / 1000.0));
        }
        if let Some(n) = cfg.get_usize("coordinator", "retry_attempts")? {
            anyhow::ensure!(n > 0, "coordinator.retry_attempts must be positive");
            c.retry.attempts = n as u32;
        }
        if let Some(ms) = cfg.get_f64("coordinator", "retry_base_ms")? {
            anyhow::ensure!(
                ms.is_finite() && ms >= 0.0,
                "coordinator.retry_base_ms must be finite and non-negative, got {ms}"
            );
            c.retry.base = Duration::from_secs_f64(ms / 1000.0);
        }
        if let Some(ms) = cfg.get_f64("coordinator", "retry_cap_ms")? {
            anyhow::ensure!(
                ms.is_finite() && ms >= 0.0,
                "coordinator.retry_cap_ms must be finite and non-negative, got {ms}"
            );
            c.retry.cap = Duration::from_secs_f64(ms / 1000.0);
        }
        if let Some(f) = cfg.get_bool("coordinator", "retry_failover")? {
            c.retry.failover = f;
        }
        if let Some(p) = cfg.get_usize("plan_cache", "capacity")? {
            anyhow::ensure!(p > 0, "plan_cache.capacity must be positive");
            c.plan_capacity = p;
        }
        Ok(c)
    }
}

/// Handle for a submitted job.
pub struct JobHandle {
    pub id: JobId,
    rx: Receiver<JobResult>,
    cancel: CancelToken,
}

/// Outcome of a timed wait on a [`JobHandle`] — distinguishes "not done
/// yet" from "will never be done" so callers can retry vs. give up.
#[derive(Debug)]
pub enum WaitOutcome {
    /// The job completed (successfully or not — see [`JobResult::outputs`]).
    Ready(JobResult),
    /// The timeout elapsed; the job is still in flight — wait again.
    TimedOut,
    /// The coordinator dropped the job (worker died or shutdown); no result
    /// will ever arrive.
    Disconnected,
}

impl JobHandle {
    /// Block for the result.
    pub fn wait(self) -> anyhow::Result<JobResult> {
        self.rx.recv().context("coordinator dropped the job (shutdown?)")
    }

    /// Block with a timeout, reporting *why* no result was returned.
    pub fn wait_timeout(&self, timeout: Duration) -> WaitOutcome {
        use std::sync::mpsc::RecvTimeoutError;
        match self.rx.recv_timeout(timeout) {
            Ok(res) => WaitOutcome::Ready(res),
            Err(RecvTimeoutError::Timeout) => WaitOutcome::TimedOut,
            Err(RecvTimeoutError::Disconnected) => WaitOutcome::Disconnected,
        }
    }

    /// Request cancellation of this job: it stops at its next checkpoint
    /// (or is evicted before dispatch) and resolves
    /// [`super::job::JobError::Canceled`]. Idempotent.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }
}

/// How `admit` waits on a full submit queue.
enum Admission {
    Block,
    Try,
    Within(Duration),
}

/// The running coordinator.
pub struct Coordinator {
    submit_q: Arc<BoundedQueue<Pending>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    batcher: Mutex<Option<JoinHandle<()>>>,
    dispatcher: Arc<BatchDispatcher>,
    backend: Arc<dyn Backend>,
    plans: Arc<PlanCache>,
    default_deadline: Option<Duration>,
    submit_timeout: Option<Duration>,
    /// Weak tokens of every admitted job, so a deadline-bounded shutdown
    /// can cancel stragglers; dead entries prune on overflow.
    active: Mutex<Vec<WeakCancelToken>>,
}

impl Coordinator {
    /// Start the batcher thread over a backend; flushed batches execute as
    /// compute-pool tasks via a [`BatchDispatcher`] admitting at most
    /// `workers` batches in flight. All batches share one [`PlanCache`],
    /// so every `(kind, direction, shape)` group the batcher forms streams
    /// through a single stationary plan.
    pub fn start(config: CoordinatorConfig, backend: Arc<dyn Backend>) -> Coordinator {
        let submit_q: Arc<BoundedQueue<Pending>> = Arc::new(BoundedQueue::new(config.queue_depth));
        let metrics = Arc::new(Metrics::new());
        let plans = Arc::new(PlanCache::new(config.plan_capacity));
        let dispatcher = Arc::new(BatchDispatcher::new(
            backend.clone(),
            plans.clone(),
            metrics.clone(),
            config.workers.max(1),
            config.retry,
        ));

        let batcher = {
            let submit_q = submit_q.clone();
            let dispatcher = dispatcher.clone();
            let metrics = metrics.clone();
            let policy = config.batch;
            std::thread::Builder::new()
                .name("triada-batcher".into())
                .spawn(move || batcher_loop(submit_q, dispatcher, policy, metrics))
                .expect("spawn batcher")
        };

        Coordinator {
            submit_q,
            metrics,
            next_id: AtomicU64::new(1),
            batcher: Mutex::new(Some(batcher)),
            dispatcher,
            backend,
            plans,
            default_deadline: config.deadline,
            submit_timeout: config.submit_timeout,
            active: Mutex::new(Vec::new()),
        }
    }

    /// Which backend this coordinator serves with.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Counters of the shared plan cache.
    pub fn plan_stats(&self) -> PlanCacheStats {
        self.plans.stats()
    }

    /// The default context for submits that bring none: the configured
    /// deadline (if any) and a fresh cancel token.
    fn default_ctx(&self) -> JobContext {
        match self.default_deadline {
            Some(d) => JobContext::deadline_in(d),
            None => JobContext::new(),
        }
    }

    /// The single admission path: stamp the job, register its token for
    /// shutdown-time cancellation, and push with the requested waiting
    /// mode. A job whose deadline has already passed is rejected without
    /// ever being enqueued.
    fn admit(
        &self,
        mut job: TransformJob,
        ctx: JobContext,
        how: Admission,
    ) -> Result<JobHandle, SubmitError> {
        if ctx.expired() && !ctx.cancel.is_canceled() {
            self.metrics.record_deadline_missed();
            return Err(SubmitError::DeadlineExpired(job));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        job.id = id;
        job.submitted_at = Instant::now();
        let (tx, rx) = channel();
        let cancel = ctx.cancel.clone();
        self.register(&cancel);
        let pending = Pending { job, reply: tx, enqueued_at: Instant::now(), ctx };
        let pushed = match how {
            Admission::Block => self.submit_q.push(pending),
            Admission::Try => self.submit_q.try_push(pending),
            Admission::Within(t) => self.submit_q.push_timeout(pending, t),
        };
        match pushed {
            Ok(()) => Ok(JobHandle { id, rx, cancel }),
            Err(e) => {
                self.metrics.record_rejection();
                let closed = matches!(e, PushError::Closed(_));
                let job = e.into_inner().job;
                Err(if closed {
                    SubmitError::ShuttingDown(job)
                } else {
                    SubmitError::QueueFull(job)
                })
            }
        }
    }

    /// Submit a job under the coordinator's default context. Blocks on a
    /// full queue — forever, or up to the configured `submit_timeout_ms`.
    pub fn submit(&self, job: TransformJob) -> anyhow::Result<JobHandle> {
        let how = match self.submit_timeout {
            Some(t) => Admission::Within(t),
            None => Admission::Block,
        };
        self.admit(job, self.default_ctx(), how).map_err(anyhow::Error::new)
    }

    /// Submit with an explicit context (deadline and/or caller-held cancel
    /// token), blocking on a full queue.
    pub fn submit_ctx(
        &self,
        job: TransformJob,
        ctx: JobContext,
    ) -> Result<JobHandle, SubmitError> {
        self.admit(job, ctx, Admission::Block)
    }

    /// Non-blocking submit (load-shed fast path): typed rejection when the
    /// queue is full or the coordinator is shutting down.
    pub fn try_submit(&self, job: TransformJob) -> Result<JobHandle, SubmitError> {
        self.admit(job, self.default_ctx(), Admission::Try)
    }

    /// Non-blocking submit with an explicit context.
    pub fn try_submit_ctx(
        &self,
        job: TransformJob,
        ctx: JobContext,
    ) -> Result<JobHandle, SubmitError> {
        self.admit(job, ctx, Admission::Try)
    }

    /// Submit, waiting at most `timeout` for queue space.
    pub fn submit_within(
        &self,
        job: TransformJob,
        timeout: Duration,
    ) -> Result<JobHandle, SubmitError> {
        self.admit(job, self.default_ctx(), Admission::Within(timeout))
    }

    /// [`Coordinator::submit_within`] under a caller-built context (the
    /// HTTP front-end's backpressure fallback: `try_submit_ctx` shed, now
    /// wait a bounded moment for a slot before answering 429).
    pub fn submit_within_ctx(
        &self,
        job: TransformJob,
        ctx: JobContext,
        timeout: Duration,
    ) -> Result<JobHandle, SubmitError> {
        self.admit(job, ctx, Admission::Within(timeout))
    }

    /// Submit and wait (convenience).
    pub fn transform(&self, job: TransformJob) -> anyhow::Result<JobResult> {
        self.submit(job)?.wait()
    }

    /// Point-in-time metrics, including plan-cache counters, compute-pool
    /// gauges, microkernel dispatch counts, and any backend degradation
    /// reasons ([`super::backend::FallbackNotice`]) plus the dispatcher's
    /// retry-failover notices.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        snap.plans = self.plans.stats();
        snap.pool = crate::pool::global().stats();
        snap.kernels = crate::gemt::kernels::stats();
        snap.sparse = crate::sparse::stats();
        let mut reasons = self.backend.fallback_reasons();
        reasons.extend(self.dispatcher.fallback_reasons());
        snap.fallback_reasons = reasons;
        snap
    }

    pub fn queue_len(&self) -> usize {
        self.submit_q.len()
    }

    fn register(&self, token: &CancelToken) {
        let mut g = self.active.lock().unwrap();
        if g.len() >= 256 {
            g.retain(WeakCancelToken::is_live);
        }
        g.push(token.downgrade());
    }

    /// Cancel every job whose token is still alive (queued or in flight).
    fn cancel_active(&self) {
        let mut g = self.active.lock().unwrap();
        g.retain(|w| w.cancel());
    }

    /// Stop intake, join the batcher (which flushes and dispatches every
    /// buffered batch on its way out), then wait for all in-flight batch
    /// tasks to finish on the pool. Idempotent.
    ///
    /// Ordering matters: `close()` makes every *future* push fail typed
    /// (`ShuttingDown`), while the queue's pop side drains items that were
    /// already accepted before reporting closed — so a job raced against
    /// shutdown is either rejected at submit or answered, never silently
    /// dropped.
    fn stop(&self) {
        self.submit_q.close();
        let handle = self.batcher.lock().unwrap().take();
        if let Some(b) = handle {
            let _ = b.join();
        }
        self.dispatcher.drain();
    }

    /// Graceful shutdown: stop intake, drain every pending batch.
    pub fn shutdown(self) {
        self.stop();
    }

    /// Deadline-bounded shutdown: stop intake and drain gracefully; if
    /// draining outlasts `timeout`, cancel every straggler (each resolves
    /// [`super::job::JobError::Canceled`] at its next checkpoint) and
    /// finish the drain. Returns `true` when the drain completed without
    /// canceling anything.
    pub fn shutdown_within(self, timeout: Duration) -> bool {
        self.drain_within(timeout)
    }

    /// [`Coordinator::shutdown_within`] by reference — for owners that
    /// embed the coordinator in a shared structure (the HTTP front-end)
    /// and cannot consume it. After draining, the coordinator only
    /// rejects (`ShuttingDown`); dropping it later is a no-op.
    pub fn drain_within(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        self.submit_q.close();
        let mut graceful = true;
        let mut cancel_once = |graceful: &mut bool| {
            if *graceful {
                *graceful = false;
                self.cancel_active();
            }
        };
        let handle = self.batcher.lock().unwrap().take();
        if let Some(b) = handle {
            while !b.is_finished() {
                if Instant::now() >= deadline {
                    cancel_once(&mut graceful);
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            let _ = b.join();
        }
        while self.dispatcher.in_flight() > 0 {
            if Instant::now() >= deadline {
                cancel_once(&mut graceful);
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        graceful
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Batcher thread body: accumulate → flush on size/window → evict
/// already-interrupted jobs (each resolves its typed error without
/// consuming an execute slot) → dispatch the rest as a pool task.
/// Dispatch applies its own in-flight backpressure and never fails, so
/// every accepted job is eventually answered.
fn batcher_loop(
    submit_q: Arc<BoundedQueue<Pending>>,
    dispatcher: Arc<BatchDispatcher>,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
) {
    let mut batcher: Batcher<Pending> = Batcher::new(policy);
    let dispatch = |batch| {
        if let Some(live) = evict_interrupted(batch, &metrics) {
            dispatcher.dispatch(live);
        }
    };
    loop {
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match submit_q.pop_timeout(timeout.max(Duration::from_micros(100))) {
            Ok(pending) => {
                let key = pending.job.batch_key();
                if let Some(batch) = batcher.add(key, pending, Instant::now()) {
                    dispatch(batch);
                }
            }
            Err(PopError::Timeout) => {}
            Err(PopError::Closed) => {
                for batch in batcher.flush_all() {
                    dispatch(batch);
                }
                return;
            }
        }
        for batch in batcher.flush_expired(Instant::now()) {
            dispatch(batch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::ReferenceBackend;
    use crate::coordinator::job::JobError;
    use crate::runtime::Direction;
    use crate::tensor::Tensor3;
    use crate::transforms::TransformKind;
    use crate::util::Rng;

    fn coordinator(workers: usize) -> Coordinator {
        let cfg = CoordinatorConfig {
            workers,
            queue_depth: 64,
            batch: BatchPolicy { max_batch: 4, window: Duration::from_millis(1) },
            ..CoordinatorConfig::default()
        };
        Coordinator::start(cfg, Arc::new(ReferenceBackend))
    }

    fn job(seed: u64) -> TransformJob {
        let mut rng = Rng::new(seed);
        let x = Tensor3::random(4, 5, 6, &mut rng).to_f32();
        TransformJob::new(TransformKind::Dct2, Direction::Forward, vec![x])
    }

    #[test]
    fn single_job_roundtrip() {
        let c = coordinator(2);
        let res = c.transform(job(1)).unwrap();
        let out = res.outputs.unwrap();
        assert_eq!(out[0].shape(), (4, 5, 6));
        assert!(res.latency_s >= 0.0);
        c.shutdown();
    }

    #[test]
    fn many_concurrent_jobs_all_complete() {
        let c = Arc::new(coordinator(4));
        let handles: Vec<_> = (0..40).map(|i| c.submit(job(i)).unwrap()).collect();
        let mut ids = std::collections::HashSet::new();
        for h in handles {
            let r = h.wait().unwrap();
            assert!(r.outputs.is_ok());
            assert!(ids.insert(r.id), "duplicate result id {}", r.id);
        }
        let snap = c.metrics();
        assert_eq!(snap.completed, 40);
        assert_eq!(snap.failed, 0);
        assert!(snap.mean_batch_size >= 1.0);
        Arc::try_unwrap(c).ok().unwrap().shutdown();
    }

    #[test]
    fn batching_groups_compatible_jobs() {
        let c = coordinator(1);
        let handles: Vec<_> = (0..8).map(|i| c.submit(job(i)).unwrap()).collect();
        let mut max_batch = 0;
        for h in handles {
            max_batch = max_batch.max(h.wait().unwrap().batch_size);
        }
        assert!(max_batch >= 2, "no batching observed (max={max_batch})");
        c.shutdown();
    }

    #[test]
    fn invalid_jobs_fail_without_poisoning() {
        let c = coordinator(2);
        let bad = TransformJob::new(TransformKind::Dwht, Direction::Forward, vec![Tensor3::zeros(3, 3, 3)]);
        let r = c.transform(bad).unwrap();
        assert!(r.outputs.is_err());
        // still serving
        let ok = c.transform(job(9)).unwrap();
        assert!(ok.outputs.is_ok());
        c.shutdown();
    }

    #[test]
    fn shutdown_rejects_new_jobs() {
        let c = coordinator(1);
        let q = c.submit_q.clone();
        c.shutdown();
        assert!(q
            .try_push(Pending {
                job: job(1),
                reply: channel().0,
                enqueued_at: Instant::now(),
                ctx: JobContext::default(),
            })
            .is_err());
    }

    #[test]
    fn submit_after_shutdown_is_typed_shutting_down() {
        let c = coordinator(1);
        c.submit_q.close();
        match c.try_submit(job(2)) {
            Err(SubmitError::ShuttingDown(j)) => assert_eq!(j.kind, TransformKind::Dct2),
            other => panic!("expected ShuttingDown, got {other:?}"),
        }
        match c.submit_within(job(3), Duration::from_millis(5)) {
            Err(SubmitError::ShuttingDown(_)) => {}
            other => panic!("expected ShuttingDown, got {other:?}"),
        }
    }

    #[test]
    fn pre_expired_deadline_is_rejected_without_enqueue() {
        let c = coordinator(1);
        let ctx = JobContext::with_deadline(Instant::now() - Duration::from_millis(1));
        match c.submit_ctx(job(4), ctx) {
            Err(SubmitError::DeadlineExpired(_)) => {}
            other => panic!("expected DeadlineExpired, got {other:?}"),
        }
        let snap = c.metrics();
        assert_eq!(snap.deadline_missed, 1);
        assert_eq!(snap.completed + snap.failed, 0, "nothing was enqueued");
        c.shutdown();
    }

    #[test]
    fn pre_canceled_job_resolves_typed_canceled() {
        let c = coordinator(1);
        let ctx = JobContext::new();
        ctx.cancel.cancel();
        let h = c.submit_ctx(job(5), ctx).expect("canceled jobs are admitted");
        let res = h.wait().unwrap();
        assert_eq!(res.job_error(), Some(JobError::Canceled));
        assert_eq!(c.metrics().canceled, 1);
        c.shutdown();
    }

    #[test]
    fn handle_cancel_resolves_typed_or_completes() {
        // Cancellation races execution: the job must resolve either
        // completed or typed-canceled, never hang or drop.
        let c = coordinator(1);
        let h = c.submit(job(6)).unwrap();
        h.cancel();
        let res = h.wait().unwrap();
        match res.job_error() {
            Some(JobError::Canceled) | None => {}
            other => panic!("unexpected resolution {other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn default_deadline_applies_to_plain_submit() {
        let cfg = CoordinatorConfig {
            workers: 1,
            deadline: Some(Duration::from_secs(3600)),
            ..CoordinatorConfig::default()
        };
        let c = Coordinator::start(cfg, Arc::new(ReferenceBackend));
        let res = c.transform(job(7)).unwrap();
        assert!(res.outputs.is_ok(), "a generous deadline never interrupts");
        c.shutdown();
    }

    #[test]
    fn shutdown_within_is_graceful_when_idle() {
        let c = coordinator(2);
        let h = c.submit(job(8)).unwrap();
        assert!(h.wait().unwrap().outputs.is_ok());
        assert!(c.shutdown_within(Duration::from_secs(5)), "idle drain must be graceful");
    }

    #[test]
    fn submit_during_shutdown_never_silently_drops() {
        // Satellite regression: jobs pushed concurrently with close() are
        // either rejected typed (ShuttingDown/QueueFull) or answered —
        // every accepted handle resolves, no Disconnected leaks.
        for round in 0..8 {
            let c = Arc::new(coordinator(2));
            let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
            let submitters: Vec<_> = (0..4)
                .map(|t| {
                    let c = c.clone();
                    let stop = stop.clone();
                    std::thread::spawn(move || {
                        let mut accepted = Vec::new();
                        let mut i = 0u64;
                        while !stop.load(Ordering::Relaxed) && i < 500 {
                            i += 1;
                            match c.try_submit(job(round * 1000 + t * 100 + i)) {
                                Ok(h) => accepted.push(h),
                                Err(SubmitError::ShuttingDown(_)) => break,
                                Err(SubmitError::QueueFull(_)) => {
                                    std::thread::yield_now();
                                }
                                Err(SubmitError::DeadlineExpired(_)) => {
                                    unreachable!("no deadline configured")
                                }
                            }
                        }
                        accepted
                    })
                })
                .collect();
            // Let submitters race the close for a moment.
            std::thread::sleep(Duration::from_millis(2));
            c.submit_q.close();
            stop.store(true, Ordering::Relaxed);
            let handles: Vec<_> =
                submitters.into_iter().flat_map(|t| t.join().unwrap()).collect();
            let accepted = handles.len();
            for h in handles {
                assert!(
                    h.wait().is_ok(),
                    "accepted job dropped during shutdown (round {round}, {accepted} accepted)"
                );
            }
            Arc::try_unwrap(c).ok().unwrap().shutdown();
        }
    }

    #[test]
    fn config_from_file_section() {
        let cfg = crate::config::Config::parse(
            "[coordinator]\nworkers = 3\nqueue_depth = 7\nmax_batch = 5\nbatch_window_ms = 4\n\n[plan_cache]\ncapacity = 9\n",
        )
        .unwrap();
        let c = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(c.workers, 3);
        assert_eq!(c.queue_depth, 7);
        assert_eq!(c.batch.max_batch, 5);
        assert_eq!(c.batch.window, Duration::from_millis(4));
        assert_eq!(c.plan_capacity, 9);
        assert_eq!(c.deadline, None);
        assert_eq!(c.submit_timeout, None);
    }

    #[test]
    fn config_reads_robustness_keys() {
        let cfg = crate::config::Config::parse(
            "[coordinator]\ndeadline_ms = 250\nsubmit_timeout_ms = 10\nretry_attempts = 5\nretry_base_ms = 1\nretry_cap_ms = 8\nretry_failover = false\n",
        )
        .unwrap();
        let c = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(c.deadline, Some(Duration::from_millis(250)));
        assert_eq!(c.submit_timeout, Some(Duration::from_millis(10)));
        assert_eq!(c.retry.attempts, 5);
        assert_eq!(c.retry.base, Duration::from_millis(1));
        assert_eq!(c.retry.cap, Duration::from_millis(8));
        assert!(!c.retry.failover);
        // 0 means "off" for the optional durations.
        let off = crate::config::Config::parse(
            "[coordinator]\ndeadline_ms = 0\nsubmit_timeout_ms = 0\n",
        )
        .unwrap();
        let c = CoordinatorConfig::from_config(&off).unwrap();
        assert_eq!(c.deadline, None);
        assert_eq!(c.submit_timeout, None);
        // Bad values are typed config errors.
        for bad in ["deadline_ms = -1", "retry_attempts = 0", "retry_base_ms = nan"] {
            let cfg =
                crate::config::Config::parse(&format!("[coordinator]\n{bad}\n")).unwrap();
            assert!(CoordinatorConfig::from_config(&cfg).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn config_rejects_zero_workers() {
        let cfg = crate::config::Config::parse("[coordinator]\nworkers = 0\n").unwrap();
        assert!(CoordinatorConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn config_rejects_zero_plan_capacity_and_defaults_when_absent() {
        let zero = crate::config::Config::parse("[plan_cache]\ncapacity = 0\n").unwrap();
        assert!(CoordinatorConfig::from_config(&zero).is_err());
        let empty = crate::config::Config::parse("").unwrap();
        let c = CoordinatorConfig::from_config(&empty).unwrap();
        assert_eq!(c.plan_capacity, super::DEFAULT_PLAN_CAPACITY);
    }

    #[test]
    fn coordinator_metrics_surface_plan_cache_counters() {
        let c = coordinator(2);
        for i in 0..6 {
            let r = c.transform(job(20 + i)).unwrap();
            assert!(r.outputs.is_ok());
        }
        let snap = c.metrics();
        assert_eq!(snap.plans.builds, 1, "one shape/kind/direction = one plan build");
        assert!(snap.plans.hits + snap.plans.misses >= 1);
        assert_eq!(c.plan_stats().builds, 1);
        assert!(snap.fallback_reasons.is_empty(), "reference never degrades");
        // Batches ran as compute-pool tasks, so the pool gauges are live.
        assert_eq!(snap.pool.workers, crate::pool::global().width());
        assert!(snap.pool.executed >= 1, "batch tasks must show in pool gauges");
        // Every transform dispatched microkernels, so their counters are live.
        assert!(
            snap.kernels.scalar_dispatches + snap.kernels.wide_dispatches >= 1,
            "transforms must show in kernel dispatch counts"
        );
        assert!(!snap.kernels.selected.is_empty() && !snap.kernels.isa.is_empty());
        c.shutdown();
    }

    #[test]
    fn config_rejects_negative_or_nonfinite_batch_window() {
        for bad in ["-1", "-0.25", "nan", "inf", "-inf"] {
            let cfg = crate::config::Config::parse(&format!(
                "[coordinator]\nbatch_window_ms = {bad}\n"
            ))
            .unwrap();
            assert!(
                CoordinatorConfig::from_config(&cfg).is_err(),
                "batch_window_ms = {bad} must be rejected"
            );
        }
        // Zero is a legal "flush immediately" window, not a panic.
        let zero = crate::config::Config::parse("[coordinator]\nbatch_window_ms = 0\n").unwrap();
        let c = CoordinatorConfig::from_config(&zero).unwrap();
        assert_eq!(c.batch.window, Duration::ZERO);
    }

    #[test]
    fn wait_timeout_times_out_then_delivers() {
        let c = coordinator(1);
        let h = c.submit(job(11)).unwrap();
        // Eventually the result arrives; every pre-delivery poll must be
        // TimedOut (never Disconnected — the worker pool is healthy).
        let mut delivered = false;
        for _ in 0..2000 {
            match h.wait_timeout(Duration::from_millis(5)) {
                WaitOutcome::Ready(res) => {
                    assert!(res.outputs.is_ok());
                    delivered = true;
                    break;
                }
                WaitOutcome::TimedOut => continue,
                WaitOutcome::Disconnected => panic!("healthy pool must not disconnect"),
            }
        }
        assert!(delivered, "job never completed");
        c.shutdown();
    }
}
