//! The process-wide **compute pool** — one long-lived work-stealing worker
//! pool that every layer schedules onto, mirroring the paper's central
//! resource model: a TriADA device is a *fixed* physical mesh of cells and
//! problems are mapped onto it, never the other way around (§3). Before
//! this module, `gemt::engine` and `gemt::shard` spawned a fresh
//! `std::thread::scope` pool per stage per request while the coordinator
//! ran its own per-worker OS threads on top — job-level and intra-plan
//! parallelism oversubscribed each other, and small problems paid thread
//! spawn cost on every call.
//!
//! Shape of the pool (std-only — no rayon/crossbeam offline):
//!
//! * **Per-worker deques + a global injector.** A task submitted from a
//!   pool worker lands on that worker's own deque (kept hot, LIFO-adjacent
//!   work); tasks from outside land on the shared injector. An idle worker
//!   drains its own deque front, then the injector, then **steals** from
//!   the back of a sibling's deque. All queue state sits behind one mutex
//!   (the coordinator's `BoundedQueue` discipline): at worker counts ≤ the
//!   host's core count the lock is uncontended relative to panel-sized
//!   tasks, and correctness is auditable.
//! * **Condvar parking.** Idle workers park on a condvar and are woken by
//!   submissions; parks/unparks are counted and surfaced in [`PoolStats`].
//! * **Scoped spawns with help-first waiting.** [`ComputePool::scope`] is
//!   the structured entry point the engine's row-band panels use: spawned
//!   closures may borrow the caller's stack (panels of a live output
//!   tensor), and `scope` does not return until every spawn has finished.
//!   A thread blocked in `scope` does not idle — it *helps*, executing
//!   pool tasks while it waits. That makes nested parallelism (a
//!   coordinator batch task that runs an engine scope on the same pool)
//!   deadlock-free at any pool width, including width 1.
//! * **Panic isolation.** A panicking detached task is caught and counted;
//!   the pool keeps serving. A panicking scoped task is captured and
//!   re-raised at the `scope` caller — the submitting layer observes its
//!   own panic, other layers are unaffected.
//! * **Per-layer share limits.** Tasks are tagged with the [`Layer`] that
//!   submitted them; an optional per-layer cap bounds how many of a
//!   layer's tasks run concurrently (excess tasks are deferred and
//!   re-injected as slots free), so one layer cannot starve the others.
//! * **Graceful shutdown.** [`ComputePool::shutdown`] drains every queued
//!   task, joins the workers, and flips the pool into inline mode: tasks
//!   submitted after shutdown run on the caller thread, so no accepted
//!   work is ever lost.
//!
//! The process-wide instance lives behind [`global`] (first use builds it;
//! [`configure_global`] installs explicit knobs if called before first
//! use). File form: the `[pool]` section — see
//! [`crate::config::Config::pool_settings`]. The `TRIADA_POOL_THREADS`
//! environment variable overrides the auto-detected width (the CI
//! scheduling matrix runs the whole test suite at width 1 and at 2× host
//! parallelism through it).
//!
//! ```
//! use triada::pool::{ComputePool, Layer, PoolConfig};
//! use std::sync::atomic::{AtomicUsize, Ordering};
//!
//! let pool = ComputePool::new(PoolConfig::with_threads(2));
//! let sum = AtomicUsize::new(0);
//! pool.scope(Layer::General, |s| {
//!     for i in 0..8 {
//!         let sum = &sum;
//!         s.spawn(move || {
//!             sum.fetch_add(i, Ordering::Relaxed);
//!         });
//!     }
//! });
//! assert_eq!(sum.load(Ordering::Relaxed), 28);
//! pool.shutdown();
//! ```

use std::cell::Cell;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Which layer submitted a task — the tag per-layer share limits and the
/// stats breakdown key off.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Layer {
    /// `gemt::engine` row-band panel tasks (stages I–III).
    Engine,
    /// `gemt::shard` tile passes.
    Shard,
    /// Coordinator batch-execution tasks.
    Coordinator,
    /// Anything else (tests, ad-hoc callers).
    General,
}

impl Layer {
    /// Number of layers (array sizing).
    pub const COUNT: usize = 4;

    /// Dense index for per-layer arrays.
    pub fn index(self) -> usize {
        match self {
            Layer::Engine => 0,
            Layer::Shard => 1,
            Layer::Coordinator => 2,
            Layer::General => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Layer::Engine => "engine",
            Layer::Shard => "shard",
            Layer::Coordinator => "coordinator",
            Layer::General => "general",
        }
    }
}

/// Pool knobs (file form: `[pool] threads / pin / engine_share /
/// shard_share / coordinator_share`, see
/// [`crate::config::Config::pool_settings`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolConfig {
    /// Worker threads; `0` means auto-detect: `TRIADA_POOL_THREADS` if set,
    /// else host parallelism capped at 8 (the cap the engine and
    /// coordinator defaults already shared).
    pub threads: usize,
    /// Request pinning workers to cores. The offline build has no
    /// `sched_setaffinity` binding, so this is accepted, documented, and
    /// warned about once — never silently dropped.
    pub pin: bool,
    /// Max concurrently *running* [`Layer::Engine`] tasks (`0` = no limit).
    pub engine_share: usize,
    /// Max concurrently running [`Layer::Shard`] tasks (`0` = no limit).
    pub shard_share: usize,
    /// Max concurrently running [`Layer::Coordinator`] tasks (`0` = no
    /// limit).
    pub coordinator_share: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            threads: 0,
            pin: false,
            engine_share: 0,
            shard_share: 0,
            coordinator_share: 0,
        }
    }
}

impl PoolConfig {
    /// Default config pinned to an explicit worker count.
    pub fn with_threads(threads: usize) -> PoolConfig {
        PoolConfig { threads, ..PoolConfig::default() }
    }

    /// Build from a parsed [`crate::config::Config`] `[pool]` section.
    pub fn from_config(cfg: &crate::config::Config) -> anyhow::Result<PoolConfig> {
        let settings = cfg.pool_settings()?;
        let mut p = PoolConfig::default();
        if let Some(t) = settings.threads {
            p.threads = t;
        }
        if let Some(pin) = settings.pin {
            p.pin = pin;
        }
        if let Some(s) = settings.engine_share {
            p.engine_share = s;
        }
        if let Some(s) = settings.shard_share {
            p.shard_share = s;
        }
        if let Some(s) = settings.coordinator_share {
            p.coordinator_share = s;
        }
        Ok(p)
    }

    /// The worker count actually used: explicit `threads` wins, then the
    /// `TRIADA_POOL_THREADS` environment override, then host parallelism
    /// capped at 8.
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            return self.threads;
        }
        if let Some(t) = env_threads() {
            return t;
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8)
    }

    fn share_limits(&self) -> [usize; Layer::COUNT] {
        let mut limits = [0usize; Layer::COUNT];
        limits[Layer::Engine.index()] = self.engine_share;
        limits[Layer::Shard.index()] = self.shard_share;
        limits[Layer::Coordinator.index()] = self.coordinator_share;
        limits
    }
}

/// `TRIADA_POOL_THREADS` override, if set to a positive integer.
fn env_threads() -> Option<usize> {
    std::env::var("TRIADA_POOL_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&t| t > 0)
}

/// Point-in-time pool gauges (surfaced in `MetricsSnapshot` and `serve`
/// output).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PoolStats {
    /// Worker thread count.
    pub workers: usize,
    /// Tasks currently queued (injector + worker deques + deferred).
    pub queue_depth: usize,
    /// Tasks accepted since the pool started.
    pub submitted: u64,
    /// Tasks executed to completion (including panicked ones).
    pub executed: u64,
    /// Tasks taken from a sibling worker's deque.
    pub stolen: u64,
    /// Times a worker parked on the condvar…
    pub parks: u64,
    /// …and woke again.
    pub unparks: u64,
    /// Detached-task panics caught (scoped-task panics re-raise at the
    /// `scope` caller instead and are not counted here).
    pub panics: u64,
    /// Tasks deferred at least once by a per-layer share limit.
    pub deferred: u64,
    /// Mean queue wait (submit → execution start), seconds.
    pub task_wait_mean_s: f64,
    /// Worst queue wait observed, seconds.
    pub task_wait_max_s: f64,
}

impl PoolStats {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        use crate::util::human;
        format!(
            "{} workers | depth={} | {} submitted / {} executed ({} stolen, {} deferred) | parks={}/{} | wait mean={} max={} | panics={}",
            self.workers,
            self.queue_depth,
            self.submitted,
            self.executed,
            self.stolen,
            self.deferred,
            self.parks,
            self.unparks,
            human::duration(self.task_wait_mean_s),
            human::duration(self.task_wait_max_s),
            self.panics,
        )
    }
}

/// A queued unit of work.
struct Task {
    layer: Layer,
    submitted: Instant,
    run: Box<dyn FnOnce() + Send + 'static>,
}

/// Queue state behind the pool mutex.
struct State {
    /// Shared FIFO for tasks submitted from outside the pool.
    injector: VecDeque<Task>,
    /// One deque per worker: owner pops the front, thieves pop the back.
    deques: Vec<VecDeque<Task>>,
    /// Tasks bounced by a per-layer share limit, awaiting a free slot.
    deferred: Vec<VecDeque<Task>>,
    /// Currently-running task count per layer.
    running: [usize; Layer::COUNT],
    /// Shutdown requested: drain and exit.
    draining: bool,
    /// Workers joined; submissions now run inline on the caller.
    terminated: bool,
    parks: u64,
    unparks: u64,
    steals: u64,
    deferrals: u64,
}

impl State {
    fn queued(&self) -> usize {
        self.injector.len()
            + self.deques.iter().map(|d| d.len()).sum::<usize>()
            + self.deferred.iter().map(|d| d.len()).sum::<usize>()
    }

    /// Admit a candidate task against the share limits: either mark it
    /// running and hand it out, or defer it and report `None`.
    fn admit(&mut self, task: Task, limits: &[usize; Layer::COUNT]) -> Option<Task> {
        let l = task.layer.index();
        if limits[l] != 0 && self.running[l] >= limits[l] {
            self.deferrals += 1;
            self.deferred[l].push_back(task);
            return None;
        }
        self.running[l] += 1;
        Some(task)
    }

    /// Take the next runnable task: own deque first (when the caller is
    /// worker `who`), then the injector, then steal from a sibling's back.
    fn take(&mut self, who: Option<usize>, limits: &[usize; Layer::COUNT]) -> Option<Task> {
        if let Some(w) = who {
            while let Some(t) = self.deques[w].pop_front() {
                if let Some(t) = self.admit(t, limits) {
                    return Some(t);
                }
            }
        }
        while let Some(t) = self.injector.pop_front() {
            if let Some(t) = self.admit(t, limits) {
                return Some(t);
            }
        }
        for j in 0..self.deques.len() {
            if who == Some(j) {
                continue;
            }
            while let Some(t) = self.deques[j].pop_back() {
                self.steals += 1;
                if let Some(t) = self.admit(t, limits) {
                    return Some(t);
                }
            }
        }
        None
    }

    /// A task of `layer` finished: free its slot and promote one deferred
    /// task of the same layer, if any.
    fn finish(&mut self, layer: Layer) -> bool {
        let l = layer.index();
        debug_assert!(self.running[l] > 0);
        self.running[l] -= 1;
        if let Some(t) = self.deferred[l].pop_front() {
            self.injector.push_front(t);
            return true; // caller must notify
        }
        false
    }
}

struct Shared {
    /// Distinguishes pools so a thread that is a worker of pool A submits
    /// to A's deque but to pool B's injector.
    id: usize,
    width: usize,
    limits: [usize; Layer::COUNT],
    state: Mutex<State>,
    work_ready: Condvar,
    submitted: AtomicU64,
    executed: AtomicU64,
    panics: AtomicU64,
    wait_sum_ns: AtomicU64,
    wait_max_ns: AtomicU64,
}

thread_local! {
    /// `(pool id, worker index)` of the pool worker running this thread.
    static WORKER: Cell<Option<(usize, usize)>> = const { Cell::new(None) };
}

static NEXT_POOL_ID: AtomicUsize = AtomicUsize::new(1);

/// A long-lived work-stealing worker pool. See the module docs for the
/// full design; the process-wide instance is [`global`].
pub struct ComputePool {
    config: PoolConfig,
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl ComputePool {
    /// Spawn a pool with the given knobs.
    pub fn new(config: PoolConfig) -> ComputePool {
        let width = config.effective_threads().max(1);
        if config.pin {
            eprintln!(
                "pool: pin = true requested, but the offline build has no core-affinity \
                 binding; continuing unpinned"
            );
        }
        let shared = Arc::new(Shared {
            id: NEXT_POOL_ID.fetch_add(1, Ordering::Relaxed),
            width,
            limits: config.share_limits(),
            state: Mutex::new(State {
                injector: VecDeque::new(),
                deques: (0..width).map(|_| VecDeque::new()).collect(),
                deferred: (0..Layer::COUNT).map(|_| VecDeque::new()).collect(),
                running: [0; Layer::COUNT],
                draining: false,
                terminated: false,
                parks: 0,
                unparks: 0,
                steals: 0,
                deferrals: 0,
            }),
            work_ready: Condvar::new(),
            submitted: AtomicU64::new(0),
            executed: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            wait_sum_ns: AtomicU64::new(0),
            wait_max_ns: AtomicU64::new(0),
        });
        let mut workers = Vec::with_capacity(width);
        for w in 0..width {
            let shared = shared.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("triada-pool-{w}"))
                    .spawn(move || worker_main(shared, w))
                    .expect("spawn pool worker"),
            );
        }
        ComputePool { config, shared, workers: Mutex::new(workers) }
    }

    /// Worker thread count.
    pub fn width(&self) -> usize {
        self.shared.width
    }

    /// The knobs this pool was built with.
    pub fn config(&self) -> &PoolConfig {
        &self.config
    }

    /// Submit a detached (fire-and-forget) task. Panics inside it are
    /// caught and counted ([`PoolStats::panics`]); the pool keeps serving.
    /// After [`ComputePool::shutdown`] the task runs inline on the caller.
    pub fn submit(&self, layer: Layer, f: impl FnOnce() + Send + 'static) {
        self.submit_task(Task { layer, submitted: Instant::now(), run: Box::new(f) });
    }

    fn submit_task(&self, task: Task) {
        let sh = &self.shared;
        let mut task = Some(task);
        {
            let mut st = sh.state.lock().unwrap();
            if !st.terminated {
                sh.submitted.fetch_add(1, Ordering::Relaxed);
                let t = task.take().unwrap();
                match WORKER.with(|w| w.get()) {
                    Some((pool_id, idx)) if pool_id == sh.id => st.deques[idx].push_back(t),
                    _ => st.injector.push_back(t),
                }
            }
        }
        match task {
            // Post-shutdown: execute on the caller so accepted work is
            // never lost (the running count is bumped directly — share
            // limits no longer apply once the workers are gone).
            Some(t) => {
                sh.submitted.fetch_add(1, Ordering::Relaxed);
                sh.state.lock().unwrap().running[t.layer.index()] += 1;
                execute(sh, t);
            }
            None => sh.work_ready.notify_one(),
        }
    }

    /// Run `op`, which may spawn borrowing closures onto the pool via the
    /// provided [`Scope`]; returns only after every spawned task finished.
    /// While waiting, the calling thread executes other pool tasks
    /// (help-first), so scopes nest without deadlock at any width. A panic
    /// in any spawned task (or in `op` itself) is re-raised here after all
    /// tasks completed.
    pub fn scope<'scope, R>(
        &'scope self,
        layer: Layer,
        op: impl FnOnce(&Scope<'scope>) -> R,
    ) -> R {
        let scope = Scope {
            pool: self,
            layer,
            state: Arc::new(ScopeState {
                pending: Mutex::new(0),
                done: Condvar::new(),
                panic: Mutex::new(None),
            }),
            _marker: PhantomData,
        };
        let result = catch_unwind(AssertUnwindSafe(|| op(&scope)));
        // Whatever `op` did, every spawn must complete before the borrows
        // captured by the tasks can expire.
        self.wait_scope(&scope.state);
        if let Some(p) = scope.state.panic.lock().unwrap().take() {
            resume_unwind(p);
        }
        match result {
            Ok(r) => r,
            Err(p) => resume_unwind(p),
        }
    }

    /// Help-first wait: run pool tasks while the scope has pending spawns;
    /// park briefly when nothing is runnable (the short timeout covers the
    /// window where a task is taken by another worker between our check
    /// and the wait).
    fn wait_scope(&self, scope: &Arc<ScopeState>) {
        loop {
            if *scope.pending.lock().unwrap() == 0 {
                return;
            }
            if self.help_one() {
                continue;
            }
            let g = scope.pending.lock().unwrap();
            if *g == 0 {
                return;
            }
            let _ = scope.done.wait_timeout(g, Duration::from_millis(1)).unwrap();
        }
    }

    /// Try to execute one queued task on the current thread. Used by scope
    /// waiters; also the shutdown sweep.
    fn help_one(&self) -> bool {
        let who = match WORKER.with(|w| w.get()) {
            Some((pool_id, idx)) if pool_id == self.shared.id => Some(idx),
            _ => None,
        };
        let task = self.shared.state.lock().unwrap().take(who, &self.shared.limits);
        match task {
            Some(task) => {
                execute(&self.shared, task);
                true
            }
            None => false,
        }
    }

    /// Point-in-time gauges.
    pub fn stats(&self) -> PoolStats {
        let (queue_depth, parks, unparks, steals, deferrals) = {
            let st = self.shared.state.lock().unwrap();
            (st.queued(), st.parks, st.unparks, st.steals, st.deferrals)
        };
        let executed = self.shared.executed.load(Ordering::Relaxed);
        let wait_sum = self.shared.wait_sum_ns.load(Ordering::Relaxed);
        PoolStats {
            workers: self.shared.width,
            queue_depth,
            submitted: self.shared.submitted.load(Ordering::Relaxed),
            executed,
            stolen: steals,
            parks,
            unparks,
            panics: self.shared.panics.load(Ordering::Relaxed),
            deferred: deferrals,
            task_wait_mean_s: if executed == 0 {
                0.0
            } else {
                wait_sum as f64 / executed as f64 / 1e9
            },
            task_wait_max_s: self.shared.wait_max_ns.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }

    /// Graceful shutdown: drain every queued task, join the workers, then
    /// flip to inline mode (later submissions run on the caller thread).
    /// Idempotent.
    pub fn shutdown(&self) {
        let handles = std::mem::take(&mut *self.workers.lock().unwrap());
        self.shared.state.lock().unwrap().draining = true;
        self.shared.work_ready.notify_all();
        for h in handles {
            let _ = h.join();
        }
        // Sweep any task that raced past the exiting workers (or was
        // re-injected from the deferred queues after they left). The
        // terminated flag flips under the same lock acquisition that
        // witnesses empty queues, so a concurrent submit either lands
        // before the flip (and is swept here) or after it (and runs
        // inline on the submitter) — never stranded in between.
        loop {
            while self.help_one() {}
            let st = self.shared.state.lock().unwrap();
            if st.queued() == 0 {
                let mut st = st;
                st.terminated = true;
                return;
            }
            // Non-empty but nothing takeable: a deferred task is waiting
            // on a still-running sibling (e.g. a scope on another thread)
            // to finish and promote it.
            let _ = self
                .shared
                .work_ready
                .wait_timeout(st, Duration::from_millis(1))
                .unwrap();
        }
    }
}

impl Drop for ComputePool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl std::fmt::Debug for ComputePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ComputePool")
            .field("config", &self.config)
            .field("width", &self.shared.width)
            .finish()
    }
}

/// Decrements the per-layer running count (and promotes a deferred task)
/// even when the task panics.
struct RunGuard<'a> {
    shared: &'a Shared,
    layer: Layer,
}

impl Drop for RunGuard<'_> {
    fn drop(&mut self) {
        self.shared.executed.fetch_add(1, Ordering::Relaxed);
        let promoted = self.shared.state.lock().unwrap().finish(self.layer);
        if promoted {
            self.shared.work_ready.notify_one();
        }
    }
}

/// Run one admitted task: record queue wait, isolate panics, settle the
/// running count via [`RunGuard`].
fn execute(shared: &Shared, task: Task) {
    let wait_ns = task.submitted.elapsed().as_nanos().min(u64::MAX as u128) as u64;
    shared.wait_sum_ns.fetch_add(wait_ns, Ordering::Relaxed);
    shared.wait_max_ns.fetch_max(wait_ns, Ordering::Relaxed);
    let _guard = RunGuard { shared, layer: task.layer };
    if catch_unwind(AssertUnwindSafe(task.run)).is_err() {
        shared.panics.fetch_add(1, Ordering::Relaxed);
    }
}

/// Worker body: take → execute → park when idle → exit when draining and
/// nothing is queued.
fn worker_main(shared: Arc<Shared>, idx: usize) {
    WORKER.with(|w| w.set(Some((shared.id, idx))));
    loop {
        let task = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(t) = st.take(Some(idx), &shared.limits) {
                    break Some(t);
                }
                if st.draining {
                    break None;
                }
                st.parks += 1;
                st = shared.work_ready.wait(st).unwrap();
                st.unparks += 1;
            }
        };
        match task {
            Some(task) => execute(&shared, task),
            None => return,
        }
    }
}

/// State shared between a [`Scope`] and its spawned tasks.
struct ScopeState {
    pending: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>,
}

impl ScopeState {
    fn finish_one(&self) {
        let mut g = self.pending.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.done.notify_all();
        }
    }
}

/// Spawn handle passed to the closure of [`ComputePool::scope`]. Spawned
/// closures may borrow anything that outlives the `scope` call.
pub struct Scope<'scope> {
    pool: &'scope ComputePool,
    layer: Layer,
    state: Arc<ScopeState>,
    /// Invariant in `'scope` (the `&mut`), like `rayon::Scope` /
    /// `std::thread::Scope`: keeps callers from shrinking the lifetime the
    /// spawned borrows must survive.
    _marker: PhantomData<&'scope mut &'scope ()>,
}

impl<'scope> Scope<'scope> {
    /// Spawn a task onto the pool. The closure may borrow data of
    /// lifetime `'scope`; the enclosing [`ComputePool::scope`] call blocks
    /// (helping) until it has run.
    pub fn spawn(&self, body: impl FnOnce() + Send + 'scope) {
        *self.state.pending.lock().unwrap() += 1;
        let state = Arc::clone(&self.state);
        let layer = self.layer;
        let body = move || {
            // Fault-injection point: compute-layer (engine/shard) tasks
            // only. The panic lands inside this task's catch_unwind, so
            // the scope still settles and re-raises at its caller — the
            // path the dispatcher's retry/failover must absorb.
            if matches!(layer, Layer::Engine | Layer::Shard)
                && crate::faults::pool_task_should_panic()
            {
                panic!("injected pool-task panic");
            }
            body()
        };
        let task: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
            if let Err(p) = catch_unwind(AssertUnwindSafe(body)) {
                // First panic wins; later ones are dropped (same policy as
                // std::thread::scope's "first to propagate").
                let mut slot = state.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
            state.finish_one();
        });
        // SAFETY: the task is type-erased to 'static so it can sit in the
        // pool's queues, but `ComputePool::scope` does not return until
        // `pending` reaches zero — i.e. until this closure has run and
        // dropped — so every `'scope` borrow it captures is live for as
        // long as the closure exists. This is the rayon/std scoped-spawn
        // construction. Shutdown cannot strand it either: drained pools
        // run submissions inline on the caller.
        let task: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(task) };
        self.pool.submit_task(Task {
            layer: self.layer,
            submitted: Instant::now(),
            run: task,
        });
    }

    /// The layer this scope tags its spawns with.
    pub fn layer(&self) -> Layer {
        self.layer
    }
}

static GLOBAL: OnceLock<ComputePool> = OnceLock::new();

/// The process-wide pool. Built on first use from [`PoolConfig::default`]
/// (honoring `TRIADA_POOL_THREADS`) unless [`configure_global`] installed
/// explicit knobs first. Never shut down — it lives for the process.
pub fn global() -> &'static ComputePool {
    GLOBAL.get_or_init(|| ComputePool::new(PoolConfig::default()))
}

/// Install explicit knobs for the process-wide pool. Returns `true` if
/// this call built the pool, `false` if it already existed (first
/// configuration wins; the running pool is returned by [`global`]).
pub fn configure_global(config: PoolConfig) -> bool {
    let mut built = false;
    GLOBAL.get_or_init(|| {
        built = true;
        ComputePool::new(config)
    });
    built
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn detached_tasks_run() {
        let pool = ComputePool::new(PoolConfig::with_threads(2));
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..16 {
            let tx = tx.clone();
            pool.submit(Layer::General, move || tx.send(i).unwrap());
        }
        let mut got: Vec<usize> = (0..16).map(|_| rx.recv().unwrap()).collect();
        got.sort_unstable();
        assert_eq!(got, (0..16).collect::<Vec<_>>());
        let stats = pool.stats();
        assert_eq!(stats.submitted, 16);
        assert_eq!(stats.workers, 2);
        pool.shutdown();
        assert_eq!(pool.stats().executed, 16);
    }

    #[test]
    fn scope_runs_borrowing_closures() {
        let pool = ComputePool::new(PoolConfig::with_threads(3));
        let mut data = vec![0usize; 10];
        pool.scope(Layer::General, |s| {
            for (i, slot) in data.iter_mut().enumerate() {
                s.spawn(move || *slot = i * i);
            }
        });
        assert_eq!(data, (0..10).map(|i| i * i).collect::<Vec<_>>());
        pool.shutdown();
    }

    #[test]
    fn scope_panic_propagates_but_pool_survives() {
        let pool = ComputePool::new(PoolConfig::with_threads(2));
        let err = catch_unwind(AssertUnwindSafe(|| {
            pool.scope(Layer::General, |s| {
                s.spawn(|| panic!("scoped boom"));
                s.spawn(|| {});
            });
        }));
        assert!(err.is_err(), "scoped panic must re-raise at the scope caller");
        // Pool still serves.
        let ran = AtomicUsize::new(0);
        pool.scope(Layer::General, |s| {
            let ran = &ran;
            s.spawn(move || {
                ran.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
        pool.shutdown();
    }

    #[test]
    fn detached_panic_is_isolated_and_counted() {
        let pool = ComputePool::new(PoolConfig::with_threads(1));
        pool.submit(Layer::General, || panic!("detached boom"));
        let (tx, rx) = std::sync::mpsc::channel();
        pool.submit(Layer::General, move || tx.send(7usize).unwrap());
        assert_eq!(rx.recv().unwrap(), 7);
        assert_eq!(pool.stats().panics, 1);
        pool.shutdown();
    }

    #[test]
    fn shutdown_drains_then_runs_inline() {
        let pool = ComputePool::new(PoolConfig::with_threads(2));
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..32 {
            let count = count.clone();
            pool.submit(Layer::General, move || {
                count.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.shutdown();
        assert_eq!(count.load(Ordering::Relaxed), 32, "shutdown must drain queued tasks");
        // Post-shutdown submissions run inline, never lost.
        let count2 = count.clone();
        pool.submit(Layer::General, move || {
            count2.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 33);
    }

    #[test]
    fn share_limit_defers_but_completes() {
        let cfg = PoolConfig { threads: 4, engine_share: 1, ..PoolConfig::default() };
        let pool = ComputePool::new(cfg);
        let count = AtomicUsize::new(0);
        pool.scope(Layer::Engine, |s| {
            for _ in 0..24 {
                let count = &count;
                s.spawn(move || {
                    count.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(count.load(Ordering::Relaxed), 24);
        pool.shutdown();
    }

    #[test]
    fn nested_scope_on_width_1_pool_completes() {
        // A detached task that itself opens a scope on the same width-1
        // pool: the scope waiter must help-execute its own spawns.
        let pool = Arc::new(ComputePool::new(PoolConfig::with_threads(1)));
        let (tx, rx) = std::sync::mpsc::channel();
        let inner = pool.clone();
        pool.submit(Layer::Coordinator, move || {
            let total = AtomicUsize::new(0);
            inner.scope(Layer::Engine, |s| {
                for i in 1..=5 {
                    let total = &total;
                    s.spawn(move || {
                        total.fetch_add(i, Ordering::Relaxed);
                    });
                }
            });
            tx.send(total.load(Ordering::Relaxed)).unwrap();
        });
        assert_eq!(rx.recv().unwrap(), 15);
        pool.shutdown();
    }

    #[test]
    fn config_from_ini_section() {
        let cfg = crate::config::Config::parse(
            "[pool]\nthreads = 3\npin = false\nengine_share = 2\ncoordinator_share = 1\n",
        )
        .unwrap();
        let p = PoolConfig::from_config(&cfg).unwrap();
        assert_eq!(p.threads, 3);
        assert!(!p.pin);
        assert_eq!(p.engine_share, 2);
        assert_eq!(p.shard_share, 0);
        assert_eq!(p.coordinator_share, 1);
        let empty = crate::config::Config::parse("").unwrap();
        assert_eq!(PoolConfig::from_config(&empty).unwrap(), PoolConfig::default());
    }

    #[test]
    fn effective_threads_explicit_wins() {
        assert_eq!(PoolConfig::with_threads(5).effective_threads(), 5);
        assert!(PoolConfig::default().effective_threads() >= 1);
    }

    #[test]
    fn global_pool_is_shared_and_stable() {
        let a = global() as *const ComputePool;
        let b = global() as *const ComputePool;
        assert_eq!(a, b);
        assert!(global().width() >= 1);
        // After first use, configure_global cannot rebuild it.
        assert!(!configure_global(PoolConfig::with_threads(1)));
    }
}
