//! Wall-clock timing helper.

use std::time::Instant;

/// A simple monotonic timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed nanoseconds since start.
    pub fn elapsed_ns(&self) -> u128 {
        self.start.elapsed().as_nanos()
    }

    /// Restart and return the elapsed seconds of the previous lap.
    pub fn lap_s(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.elapsed_s())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_s() >= 0.004);
    }

    #[test]
    fn time_it_returns_result() {
        let (v, s) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn lap_resets() {
        let mut t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let lap = t.lap_s();
        assert!(lap > 0.0);
        assert!(t.elapsed_s() < lap + 0.5);
    }
}
