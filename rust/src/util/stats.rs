//! Summary statistics used by the bench harness and coordinator metrics.

/// Summary of a sample: robust order statistics plus mean/stddev.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub stddev: f64,
    pub p10: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns None for an empty sample.
    pub fn of(sample: &[f64]) -> Option<Summary> {
        if sample.is_empty() {
            return None;
        }
        let mut xs: Vec<f64> = sample.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Some(Summary {
            n,
            min: xs[0],
            max: xs[n - 1],
            mean,
            stddev: var.sqrt(),
            p10: percentile(&xs, 0.10),
            p50: percentile(&xs, 0.50),
            p90: percentile(&xs, 0.90),
            p95: percentile(&xs, 0.95),
            p99: percentile(&xs, 0.99),
        })
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, `q` in `[0, 1]`.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Streaming histogram with fixed log-spaced buckets, for latency metrics.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Bucket upper bounds (seconds); last bucket is +inf.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    /// Log-spaced buckets from `lo` to `hi` (seconds), `n` buckets + overflow.
    pub fn log_spaced(lo: f64, hi: f64, n: usize) -> Histogram {
        assert!(lo > 0.0 && hi > lo && n >= 2);
        let ratio = (hi / lo).powf(1.0 / (n - 1) as f64);
        let bounds: Vec<f64> = (0..n).map(|i| lo * ratio.powi(i as i32)).collect();
        let len = bounds.len() + 1;
        Histogram { bounds, counts: vec![0; len], total: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Default latency histogram: 1 µs .. 100 s, 120 buckets.
    pub fn latency() -> Histogram {
        Histogram::log_spaced(1e-6, 100.0, 120)
    }

    pub fn record(&mut self, v: f64) {
        let idx = self.bounds.partition_point(|&b| b < v);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.sum / self.total as f64 }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return if i == 0 {
                    self.bounds[0]
                } else if i >= self.bounds.len() {
                    self.max
                } else {
                    self.bounds[i]
                };
            }
        }
        self.max
    }

    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bounds.len(), other.bounds.len(), "histogram shape mismatch");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.5]).unwrap();
        assert_eq!(s.p50, 7.5);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = Histogram::latency();
        let mut v = 1e-5;
        for _ in 0..1000 {
            h.record(v);
            v *= 1.005;
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
    }

    #[test]
    fn histogram_mean() {
        let mut h = Histogram::latency();
        h.record(0.001);
        h.record(0.003);
        assert!((h.mean() - 0.002).abs() < 1e-12);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::latency();
        let mut b = Histogram::latency();
        a.record(0.01);
        b.record(0.02);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn histogram_overflow_bucket() {
        let mut h = Histogram::log_spaced(1e-3, 1.0, 10);
        h.record(50.0); // way past hi
        assert_eq!(h.count(), 1);
        assert!(h.quantile(0.99) >= 1.0);
    }
}
