//! Human-readable formatting for counts, durations, and rates.

/// Format a duration in seconds with an adaptive unit (ns/µs/ms/s).
pub fn duration(s: f64) -> String {
    if s < 0.0 {
        return format!("-{}", duration(-s));
    }
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Format a count with SI suffix (k/M/G/T).
pub fn count(n: f64) -> String {
    let a = n.abs();
    if a >= 1e12 {
        format!("{:.2}T", n / 1e12)
    } else if a >= 1e9 {
        format!("{:.2}G", n / 1e9)
    } else if a >= 1e6 {
        format!("{:.2}M", n / 1e6)
    } else if a >= 1e3 {
        format!("{:.2}k", n / 1e3)
    } else if n.fract() == 0.0 {
        format!("{}", n as i64)
    } else {
        format!("{:.2}", n)
    }
}

/// Format a rate as ops/s with SI suffix.
pub fn rate(ops_per_s: f64) -> String {
    format!("{}/s", count(ops_per_s))
}

/// Format bytes with binary suffix.
pub fn bytes(b: f64) -> String {
    let a = b.abs();
    if a >= 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2}GiB", b / (1024.0 * 1024.0 * 1024.0))
    } else if a >= 1024.0 * 1024.0 {
        format!("{:.2}MiB", b / (1024.0 * 1024.0))
    } else if a >= 1024.0 {
        format!("{:.2}KiB", b / 1024.0)
    } else {
        format!("{}B", b as i64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn durations() {
        assert_eq!(duration(2.5), "2.500s");
        assert_eq!(duration(0.0025), "2.50ms");
        assert_eq!(duration(2.5e-6), "2.50µs");
        assert_eq!(duration(2.5e-9), "2.5ns");
    }

    #[test]
    fn counts() {
        assert_eq!(count(999.0), "999");
        assert_eq!(count(1500.0), "1.50k");
        assert_eq!(count(2.5e6), "2.50M");
        assert_eq!(count(3e9), "3.00G");
        assert_eq!(count(4e12), "4.00T");
    }

    #[test]
    fn byte_fmt() {
        assert_eq!(bytes(512.0), "512B");
        assert_eq!(bytes(2048.0), "2.00KiB");
        assert_eq!(bytes(3.0 * 1024.0 * 1024.0), "3.00MiB");
    }

    #[test]
    fn rate_fmt() {
        assert_eq!(rate(1.5e6), "1.50M/s");
    }
}
