//! Small shared utilities: deterministic PRNG, statistics, timing, and
//! human-readable formatting. These exist because the offline build has no
//! `rand`/`criterion`; see DESIGN.md §Substitutions.

pub mod cancel;
pub mod human;
pub mod rng;
pub mod stats;
pub mod timer;

pub use cancel::{CancelToken, JobContext, JobError, WeakCancelToken};
pub use rng::Rng;
pub use stats::Summary;
pub use timer::Timer;
