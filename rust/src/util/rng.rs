//! Deterministic, fast pseudo-random number generation.
//!
//! SplitMix64 for seeding + xoshiro256** for the stream — the standard
//! combination used by `rand`'s SmallRng; implemented locally because the
//! offline image ships no `rand` crate. All experiments in this repo use
//! fixed seeds so every table in EXPERIMENTS.md is exactly reproducible.

/// xoshiro256** PRNG seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value (xoshiro256**).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        assert!(n > 0, "usize(0) is ill-defined");
        // Lemire's multiply-shift rejection-free mapping is fine here: the
        // bias for n << 2^64 is far below experimental noise.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform usize in [lo, hi] inclusive.
    #[inline]
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.usize(hi - lo + 1)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-300 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Split off an independent child generator (for parallel workers).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn usize_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.usize(17) < 17);
        }
        for _ in 0..1_000 {
            let v = r.usize_range(5, 9);
            assert!((5..=9).contains(&v));
        }
    }

    #[test]
    fn usize_hits_all_buckets() {
        let mut r = Rng::new(11);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.usize(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(5);
        let hits = (0..100_000).filter(|_| r.bool(0.3)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(21);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut parent = Rng::new(1);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
