//! Cooperative cancellation and deadlines for in-flight work.
//!
//! A [`JobContext`] rides with every request from submit to the innermost
//! phase/tile checkpoints of the GEMT engine: layers call
//! [`JobContext::checkpoint`] between units of work and bail out with a
//! typed [`JobError`] the moment the request is canceled or its deadline
//! passes. Checkpoints are purely cooperative — nothing is ever torn down
//! mid-write, so a run either completes bit-identical to the scalar
//! reference or stops cleanly between phases.

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Shared cancellation flag; cloning yields another handle to the same
/// flag, so a caller can keep one clone and cancel a job it already
/// submitted.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation; idempotent, wakes nothing (checkpoints poll).
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    pub fn is_canceled(&self) -> bool {
        self.flag.load(Ordering::Acquire)
    }

    /// A weak handle for registries (e.g. the coordinator's straggler
    /// list) that must not keep finished jobs' tokens alive.
    pub fn downgrade(&self) -> WeakCancelToken {
        WeakCancelToken { flag: Arc::downgrade(&self.flag) }
    }
}

/// Weak counterpart of [`CancelToken`]: cancels the job only if some
/// strong handle (the in-flight context or the caller's [`CancelToken`])
/// is still alive; dead entries prune themselves.
#[derive(Clone, Debug)]
pub struct WeakCancelToken {
    flag: std::sync::Weak<AtomicBool>,
}

impl WeakCancelToken {
    /// Cancel if the token is still alive; returns whether it was.
    pub fn cancel(&self) -> bool {
        match self.flag.upgrade() {
            Some(flag) => {
                flag.store(true, Ordering::Release);
                true
            }
            None => false,
        }
    }

    /// Is any strong handle still alive?
    pub fn is_live(&self) -> bool {
        self.flag.strong_count() > 0
    }
}

/// Why a job stopped before producing outputs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobError {
    /// The caller canceled via [`CancelToken::cancel`].
    Canceled,
    /// The deadline in the job's [`JobContext`] passed.
    DeadlineExceeded,
}

impl fmt::Display for JobError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JobError::Canceled => write!(f, "job canceled"),
            JobError::DeadlineExceeded => write!(f, "job deadline exceeded"),
        }
    }
}

impl std::error::Error for JobError {}

/// Per-request execution context: an optional absolute deadline plus a
/// cancellation token. The default context never interrupts anything.
#[derive(Clone, Debug, Default)]
pub struct JobContext {
    /// Absolute instant past which the job must not keep computing.
    pub deadline: Option<Instant>,
    /// Cooperative cancellation flag.
    pub cancel: CancelToken,
}

impl JobContext {
    /// A context with no deadline and a fresh token.
    pub fn new() -> JobContext {
        JobContext::default()
    }

    /// A context expiring at an absolute instant.
    pub fn with_deadline(deadline: Instant) -> JobContext {
        JobContext { deadline: Some(deadline), cancel: CancelToken::new() }
    }

    /// A context expiring `timeout` from now.
    pub fn deadline_in(timeout: Duration) -> JobContext {
        JobContext::with_deadline(Instant::now() + timeout)
    }

    /// Has the deadline (if any) passed?
    pub fn expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// Time left until the deadline (None = no deadline; zero = expired).
    pub fn remaining(&self) -> Option<Duration> {
        self.deadline.map(|d| d.saturating_duration_since(Instant::now()))
    }

    /// Why this job should stop, if it should. Cancellation wins over
    /// expiry when both hold (the caller's explicit signal is the more
    /// specific one).
    pub fn interrupted(&self) -> Option<JobError> {
        if self.cancel.is_canceled() {
            Some(JobError::Canceled)
        } else if self.expired() {
            Some(JobError::DeadlineExceeded)
        } else {
            None
        }
    }

    /// The cooperative checkpoint: call between phases/tiles, propagate
    /// the error to stop.
    pub fn checkpoint(&self) -> Result<(), JobError> {
        match self.interrupted() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_context_never_interrupts() {
        let ctx = JobContext::new();
        assert!(ctx.checkpoint().is_ok());
        assert!(!ctx.expired());
        assert_eq!(ctx.remaining(), None);
    }

    #[test]
    fn cancel_is_shared_across_clones() {
        let ctx = JobContext::new();
        let handle = ctx.cancel.clone();
        assert!(ctx.checkpoint().is_ok());
        handle.cancel();
        assert_eq!(ctx.checkpoint(), Err(JobError::Canceled));
    }

    #[test]
    fn deadline_expiry_is_typed() {
        let ctx = JobContext::with_deadline(Instant::now() - Duration::from_millis(1));
        assert!(ctx.expired());
        assert_eq!(ctx.checkpoint(), Err(JobError::DeadlineExceeded));
        assert_eq!(ctx.remaining(), Some(Duration::ZERO));
    }

    #[test]
    fn cancel_wins_over_expiry() {
        let ctx = JobContext::with_deadline(Instant::now() - Duration::from_millis(1));
        ctx.cancel.cancel();
        assert_eq!(ctx.checkpoint(), Err(JobError::Canceled));
    }

    #[test]
    fn future_deadline_does_not_interrupt() {
        let ctx = JobContext::deadline_in(Duration::from_secs(3600));
        assert!(ctx.checkpoint().is_ok());
        assert!(ctx.remaining().unwrap() > Duration::from_secs(3000));
    }

    #[test]
    fn weak_token_cancels_only_while_live() {
        let ctx = JobContext::new();
        let weak = ctx.cancel.downgrade();
        assert!(weak.is_live());
        assert!(weak.cancel());
        assert_eq!(ctx.checkpoint(), Err(JobError::Canceled));
        drop(ctx);
        assert!(!weak.is_live());
        assert!(!weak.cancel(), "dead token must report itself prunable");
    }
}
