//! Benchmark harness substrate (the offline image has no `criterion`;
//! see DESIGN.md §Substitutions).
//!
//! [`bench()`](bench) measures a closure with warmup + adaptive iteration count
//! and reports robust statistics; [`Table`] prints the paper-style rows the
//! E1–E9 benches regenerate (deliverable d). All benches run under
//! `cargo bench` with `harness = false`.

use crate::util::{human, Summary, Timer};

/// Configuration for a measurement.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Minimum wall-clock time to spend sampling (seconds).
    pub min_time_s: f64,
    /// Number of timed samples to collect.
    pub samples: usize,
    /// Warmup time before sampling (seconds).
    pub warmup_s: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // Modest defaults: benches print whole tables, so keep each cell fast.
        BenchConfig { min_time_s: 0.05, samples: 15, warmup_s: 0.02 }
    }
}

/// Quick config for expensive cells (fewer samples).
impl BenchConfig {
    pub fn quick() -> BenchConfig {
        BenchConfig { min_time_s: 0.01, samples: 5, warmup_s: 0.005 }
    }
}

/// Measurement result: per-iteration seconds.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub summary: Summary,
    /// Iterations per sample used.
    pub iters: u64,
}

impl Measurement {
    pub fn median_s(&self) -> f64 {
        self.summary.p50
    }

    pub fn display(&self) -> String {
        format!(
            "{} (p10 {}, p90 {}, n={})",
            human::duration(self.summary.p50),
            human::duration(self.summary.p10),
            human::duration(self.summary.p90),
            self.summary.n
        )
    }
}

/// Measure `f`, returning per-iteration statistics.
pub fn bench(cfg: &BenchConfig, mut f: impl FnMut()) -> Measurement {
    // Warmup and iteration-count calibration.
    let t = Timer::start();
    let mut calib_iters = 0u64;
    while t.elapsed_s() < cfg.warmup_s.max(1e-4) {
        f();
        calib_iters += 1;
    }
    let per_iter = t.elapsed_s() / calib_iters as f64;
    let target_sample_s = (cfg.min_time_s / cfg.samples as f64).max(1e-5);
    let iters = ((target_sample_s / per_iter).ceil() as u64).max(1);

    let mut samples = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples {
        let t = Timer::start();
        for _ in 0..iters {
            f();
        }
        samples.push(t.elapsed_s() / iters as f64);
    }
    Measurement {
        summary: Summary::of(&samples).expect("nonempty samples"),
        iters,
    }
}

/// Black-box to stop the optimizer deleting benched work (std::hint on
/// stable is enough for our data-heavy workloads).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// A fixed-width text table matching the repo's bench output style.
pub struct Table {
    title: String,
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths: headers.iter().map(|s| s.len()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    /// Render to a string (and `print` convenience below).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {:<width$} |", c, width = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &self.widths));
        let sep: Vec<String> = self.widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&line(&sep, &self.widths));
        for r in &self.rows {
            out.push_str(&line(r, &self.widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let cfg = BenchConfig::quick();
        let m = bench(&cfg, || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(m.median_s() > 0.0);
        assert!(m.iters >= 1);
        assert_eq!(m.summary.n, cfg.samples);
    }

    #[test]
    fn bench_orders_workloads_correctly() {
        let cfg = BenchConfig::quick();
        let small = bench(&cfg, || {
            let v: Vec<u64> = (0..100).collect();
            black_box(v);
        });
        let large = bench(&cfg, || {
            let v: Vec<u64> = (0..100_000).collect();
            black_box(v);
        });
        assert!(large.median_s() > small.median_s());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["shape", "steps"]);
        t.row(&["4x4x4".into(), "12".into()]);
        t.row(&["32x48x64".into(), "144".into()]);
        let s = t.render();
        assert!(s.contains("## demo"));
        assert!(s.contains("| 4x4x4"));
        assert!(s.contains("| 32x48x64 |"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
