//! Run-configuration substrate: a minimal INI/TOML-subset parser (the
//! offline image has no `serde`/`toml`; see DESIGN.md §Substitutions).
//!
//! Supported syntax: `[section]` headers, `key = value` pairs, `#`/`;`
//! comments, blank lines. Values are read back typed via the `get_*`
//! accessors. This is what `triada serve --config <file>` consumes.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context};

/// Parsed configuration: `section.key → value` (top-level keys live in the
/// empty-string section).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    values: BTreeMap<(String, String), String>,
}

impl Config {
    /// Parse from text.
    pub fn parse(text: &str) -> anyhow::Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let Some(name) = inner.strip_suffix(']') else {
                    bail!("line {}: unterminated section header: {raw:?}", lineno + 1);
                };
                section = name.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected `key = value`: {raw:?}", lineno + 1);
            };
            let key = k.trim().to_string();
            let mut val = v.trim().to_string();
            // strip one layer of quotes
            if (val.starts_with('"') && val.ends_with('"') && val.len() >= 2)
                || (val.starts_with('\'') && val.ends_with('\'') && val.len() >= 2)
            {
                val = val[1..val.len() - 1].to_string();
            }
            values.insert((section.clone(), key), val);
        }
        Ok(Config { values })
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Config::parse(&text)
    }

    /// Raw string lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.values
            .get(&(section.to_string(), key.to_string()))
            .map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).unwrap_or(default)
    }

    pub fn get_usize(&self, section: &str, key: &str) -> anyhow::Result<Option<usize>> {
        self.get(section, key)
            .map(|v| v.parse().with_context(|| format!("{section}.{key}={v:?} is not a usize")))
            .transpose()
    }

    pub fn get_f64(&self, section: &str, key: &str) -> anyhow::Result<Option<f64>> {
        self.get(section, key)
            .map(|v| v.parse().with_context(|| format!("{section}.{key}={v:?} is not a number")))
            .transpose()
    }

    pub fn get_bool(&self, section: &str, key: &str) -> anyhow::Result<Option<bool>> {
        self.get(section, key)
            .map(|v| match v.to_ascii_lowercase().as_str() {
                "true" | "yes" | "on" | "1" => Ok(true),
                "false" | "no" | "off" | "0" => Ok(false),
                other => bail!("{section}.{key}={other:?} is not a bool"),
            })
            .transpose()
    }

    /// Insert programmatically (used by CLI overrides).
    pub fn set(&mut self, section: &str, key: &str, value: &str) {
        self.values
            .insert((section.to_string(), key.to_string()), value.to_string());
    }

    /// All keys in a section.
    pub fn section_keys(&self, section: &str) -> Vec<&str> {
        self.values
            .keys()
            .filter(|(s, _)| s == section)
            .map(|(_, k)| k.as_str())
            .collect()
    }

    /// Typed view of the `[engine]` section (the blocked multi-threaded
    /// 3D-GEMT engine, `gemt::engine`, and its sharding layer,
    /// `gemt::shard`). Validates `block > 0` and `max_tile > 0`;
    /// `threads = 0` is allowed and means auto-detect.
    pub fn engine_settings(&self) -> anyhow::Result<EngineSettings> {
        let threads = self.get_usize("engine", "threads")?;
        let block = self.get_usize("engine", "block")?;
        let max_tile = self.get_usize("engine", "max_tile")?;
        if let Some(b) = block {
            anyhow::ensure!(b > 0, "engine.block must be positive");
        }
        if let Some(mt) = max_tile {
            anyhow::ensure!(mt > 0, "engine.max_tile must be positive");
        }
        Ok(EngineSettings { threads, block, max_tile })
    }

    /// Typed view of the `[pool]` section (the process-wide work-stealing
    /// compute pool, `crate::pool`). Every key is optional; `threads = 0`
    /// means auto-detect and a share of `0` means unlimited, so there is
    /// nothing to validate beyond the types.
    pub fn pool_settings(&self) -> anyhow::Result<PoolSettings> {
        Ok(PoolSettings {
            threads: self.get_usize("pool", "threads")?,
            pin: self.get_bool("pool", "pin")?,
            engine_share: self.get_usize("pool", "engine_share")?,
            shard_share: self.get_usize("pool", "shard_share")?,
            coordinator_share: self.get_usize("pool", "coordinator_share")?,
        })
    }

    /// Typed view of the `[kernels]` section (the vectorized microkernel
    /// layer, `crate::gemt::kernels`). Validates that `force` is one of
    /// `auto` / `scalar` / `wide`.
    pub fn kernel_settings(&self) -> anyhow::Result<KernelSettings> {
        let force = self.get("kernels", "force").map(|v| v.to_string());
        if let Some(f) = &force {
            anyhow::ensure!(
                matches!(f.as_str(), "auto" | "scalar" | "wide"),
                "kernels.force={f:?} is not one of auto|scalar|wide"
            );
        }
        Ok(KernelSettings { force })
    }

    /// Typed view of the `[sparse]` section (the compressed sparse tensor
    /// subsystem, `crate::sparse`). Validates that `force` is one of
    /// `auto` / `dense` / `compressed` and that `threshold` is a finite
    /// sparsity fraction in `[0, 1]`.
    pub fn sparse_settings(&self) -> anyhow::Result<SparseSettings> {
        let force = self.get("sparse", "force").map(|v| v.to_string());
        if let Some(f) = &force {
            anyhow::ensure!(
                matches!(f.as_str(), "auto" | "dense" | "compressed"),
                "sparse.force={f:?} is not one of auto|dense|compressed"
            );
        }
        let threshold = self.get_f64("sparse", "threshold")?;
        if let Some(t) = threshold {
            anyhow::ensure!(
                t.is_finite() && (0.0..=1.0).contains(&t),
                "sparse.threshold={t} must be a fraction in [0, 1]"
            );
        }
        Ok(SparseSettings { force, threshold })
    }
}

/// Parsed `[engine]` keys; `None` means "not set, use the engine default".
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineSettings {
    /// Worker threads (`Some(0)` = explicit auto-detect).
    pub threads: Option<usize>,
    /// Summation-step panel height.
    pub block: Option<usize>,
    /// Sharding tile bound: any problem dimension exceeding this is block
    /// decomposed across engine passes (`gemt::shard`).
    pub max_tile: Option<usize>,
}

/// Parsed `[kernels]` keys; `None` means "not set, use auto selection".
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct KernelSettings {
    /// Kernel choice: `"auto"` (default), `"scalar"`, or `"wide"`. The
    /// `TRIADA_KERNEL` environment variable overrides this key.
    pub force: Option<String>,
}

/// Parsed `[sparse]` keys; `None` means "not set, use auto selection".
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseSettings {
    /// Route choice: `"auto"` (default), `"dense"`, or `"compressed"`. The
    /// `TRIADA_SPARSE` environment variable overrides this key.
    pub force: Option<String>,
    /// Sparsity fraction at which auto selection routes compressed.
    pub threshold: Option<f64>,
}

/// Parsed `[pool]` keys; `None` means "not set, use the pool default".
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolSettings {
    /// Pool worker threads (`Some(0)` = explicit auto-detect).
    pub threads: Option<usize>,
    /// Request core pinning (accepted but a documented no-op offline).
    pub pin: Option<bool>,
    /// Max concurrently running engine-layer tasks (`0` = unlimited).
    pub engine_share: Option<usize>,
    /// Max concurrently running shard-layer tasks (`0` = unlimited).
    pub shard_share: Option<usize>,
    /// Max concurrently running coordinator-layer tasks (`0` = unlimited).
    pub coordinator_share: Option<usize>,
}

/// Every supported config key as `(section, key, documented default)` —
/// the source of truth `docs/CONFIG.md` is checked against by the
/// `config_md_documents_every_key_and_default` test. Defaults are rendered
/// from the live `Default` impls so the documentation cannot drift.
pub fn documented_keys() -> Vec<(&'static str, &'static str, String)> {
    let coord = crate::coordinator::CoordinatorConfig::default();
    let engine = crate::gemt::EngineConfig::default();
    let shard = crate::gemt::ShardConfig::default();
    let pool = crate::pool::PoolConfig::default();
    let faults = crate::faults::FaultPlan::default();
    let server = crate::server::ServerConfig::default();
    vec![
        ("coordinator", "workers", "auto".to_string()),
        ("coordinator", "queue_depth", coord.queue_depth.to_string()),
        ("coordinator", "max_batch", coord.batch.max_batch.to_string()),
        (
            "coordinator",
            "batch_window_ms",
            format!("{}", coord.batch.window.as_secs_f64() * 1000.0),
        ),
        ("coordinator", "deadline_ms", "0".to_string()),
        ("coordinator", "submit_timeout_ms", "0".to_string()),
        ("coordinator", "retry_attempts", coord.retry.attempts.to_string()),
        (
            "coordinator",
            "retry_base_ms",
            format!("{}", coord.retry.base.as_secs_f64() * 1000.0),
        ),
        (
            "coordinator",
            "retry_cap_ms",
            format!("{}", coord.retry.cap.as_secs_f64() * 1000.0),
        ),
        ("coordinator", "retry_failover", coord.retry.failover.to_string()),
        ("faults", "seed", faults.seed.to_string()),
        ("faults", "transient_p", faults.transient_p.to_string()),
        ("faults", "transient_max", faults.transient_max.to_string()),
        ("faults", "slow_p", faults.slow_p.to_string()),
        ("faults", "slow_ms", faults.slow_ms.to_string()),
        ("faults", "plan_panic_n", faults.plan_panic_n.to_string()),
        ("faults", "pool_panic_p", faults.pool_panic_p.to_string()),
        ("faults", "pool_panic_max", faults.pool_panic_max.to_string()),
        ("engine", "threads", engine.threads.to_string()),
        ("engine", "block", engine.block.to_string()),
        ("engine", "max_tile", shard.max_tile.to_string()),
        ("kernels", "force", "auto".to_string()),
        ("sparse", "force", "auto".to_string()),
        ("sparse", "threshold", crate::sparse::DEFAULT_SPARSE_THRESHOLD.to_string()),
        ("plan_cache", "capacity", coord.plan_capacity.to_string()),
        ("pool", "threads", pool.threads.to_string()),
        ("pool", "pin", pool.pin.to_string()),
        ("pool", "engine_share", pool.engine_share.to_string()),
        ("pool", "shard_share", pool.shard_share.to_string()),
        ("pool", "coordinator_share", pool.coordinator_share.to_string()),
        ("server", "listen", server.listen.clone()),
        ("server", "max_body_bytes", server.max_body_bytes.to_string()),
        ("server", "max_inflight_per_client", server.max_inflight_per_client.to_string()),
        ("server", "max_connections", server.max_connections.to_string()),
        (
            "server",
            "read_timeout_ms",
            format!(
                "{}",
                server.read_timeout.map_or(0.0, |d| d.as_secs_f64() * 1000.0)
            ),
        ),
        ("server", "submit_wait_ms", "0".to_string()),
        (
            "server",
            "drain_timeout_ms",
            format!("{}", server.drain_timeout.as_secs_f64() * 1000.0),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top-level
workers = 4

[coordinator]
queue_depth = 256
batch_window_ms = 2.5
esop = true
name = "prod run"

[grid]
p1 = 64
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("", "workers"), Some("4"));
        assert_eq!(c.get_usize("coordinator", "queue_depth").unwrap(), Some(256));
        assert_eq!(c.get_f64("coordinator", "batch_window_ms").unwrap(), Some(2.5));
        assert_eq!(c.get_bool("coordinator", "esop").unwrap(), Some(true));
        assert_eq!(c.get("coordinator", "name"), Some("prod run"));
        assert_eq!(c.get_usize("grid", "p1").unwrap(), Some(64));
    }

    #[test]
    fn missing_keys_are_none() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("nope", "missing"), None);
        assert_eq!(c.get_usize("grid", "p9").unwrap(), None);
        assert_eq!(c.get_or("grid", "p9", "128"), "128");
    }

    #[test]
    fn type_errors_are_reported() {
        let c = Config::parse("[a]\nx = notanumber\n").unwrap();
        assert!(c.get_usize("a", "x").is_err());
        assert!(c.get_bool("a", "x").is_err());
    }

    #[test]
    fn syntax_errors_are_reported() {
        assert!(Config::parse("[unterminated\n").is_err());
        assert!(Config::parse("no equals sign here\n").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let c = Config::parse("# c\n; c2\n\nk = v\n").unwrap();
        assert_eq!(c.get("", "k"), Some("v"));
    }

    #[test]
    fn set_and_section_keys() {
        let mut c = Config::default();
        c.set("s", "a", "1");
        c.set("s", "b", "2");
        assert_eq!(c.section_keys("s"), vec!["a", "b"]);
    }

    #[test]
    fn engine_settings_parse_and_default() {
        let c = Config::parse("[engine]\nthreads = 4\nblock = 32\nmax_tile = 96\n").unwrap();
        let s = c.engine_settings().unwrap();
        assert_eq!(
            s,
            EngineSettings { threads: Some(4), block: Some(32), max_tile: Some(96) }
        );
        let empty = Config::parse("").unwrap();
        assert_eq!(empty.engine_settings().unwrap(), EngineSettings::default());
    }

    #[test]
    fn engine_settings_validate() {
        let zero_block = Config::parse("[engine]\nblock = 0\n").unwrap();
        assert!(zero_block.engine_settings().is_err());
        let zero_tile = Config::parse("[engine]\nmax_tile = 0\n").unwrap();
        assert!(zero_tile.engine_settings().is_err());
        let auto_threads = Config::parse("[engine]\nthreads = 0\n").unwrap();
        assert_eq!(auto_threads.engine_settings().unwrap().threads, Some(0));
        let junk = Config::parse("[engine]\nthreads = lots\n").unwrap();
        assert!(junk.engine_settings().is_err());
    }

    #[test]
    fn pool_settings_parse_and_default() {
        let c = Config::parse(
            "[pool]\nthreads = 6\npin = true\nengine_share = 4\nshard_share = 2\ncoordinator_share = 1\n",
        )
        .unwrap();
        let s = c.pool_settings().unwrap();
        assert_eq!(
            s,
            PoolSettings {
                threads: Some(6),
                pin: Some(true),
                engine_share: Some(4),
                shard_share: Some(2),
                coordinator_share: Some(1),
            }
        );
        let empty = Config::parse("").unwrap();
        assert_eq!(empty.pool_settings().unwrap(), PoolSettings::default());
        // 0 is meaningful everywhere (auto / unlimited), never an error.
        let zeros = Config::parse("[pool]\nthreads = 0\nengine_share = 0\n").unwrap();
        let s = zeros.pool_settings().unwrap();
        assert_eq!(s.threads, Some(0));
        assert_eq!(s.engine_share, Some(0));
        // Types are still enforced.
        let junk = Config::parse("[pool]\nthreads = many\n").unwrap();
        assert!(junk.pool_settings().is_err());
        let junk = Config::parse("[pool]\npin = maybe\n").unwrap();
        assert!(junk.pool_settings().is_err());
    }

    #[test]
    fn kernel_settings_parse_and_validate() {
        for (text, want) in [
            ("", None),
            ("[kernels]\nforce = auto\n", Some("auto")),
            ("[kernels]\nforce = scalar\n", Some("scalar")),
            ("[kernels]\nforce = \"wide\"\n", Some("wide")),
        ] {
            let c = Config::parse(text).unwrap();
            assert_eq!(
                c.kernel_settings().unwrap(),
                KernelSettings { force: want.map(str::to_string) },
                "{text:?}"
            );
        }
        let bad = Config::parse("[kernels]\nforce = avx512\n").unwrap();
        assert!(bad.kernel_settings().is_err());
    }

    #[test]
    fn sparse_settings_parse_and_validate() {
        for (text, want) in [
            ("", SparseSettings::default()),
            (
                "[sparse]\nforce = compressed\n",
                SparseSettings { force: Some("compressed".to_string()), threshold: None },
            ),
            (
                "[sparse]\nforce = \"dense\"\nthreshold = 0.75\n",
                SparseSettings { force: Some("dense".to_string()), threshold: Some(0.75) },
            ),
            (
                "[sparse]\nthreshold = 1.0\n",
                SparseSettings { force: None, threshold: Some(1.0) },
            ),
        ] {
            let c = Config::parse(text).unwrap();
            assert_eq!(c.sparse_settings().unwrap(), want, "{text:?}");
        }
        for bad in [
            "[sparse]\nforce = csr\n",
            "[sparse]\nthreshold = 1.5\n",
            "[sparse]\nthreshold = -0.1\n",
            "[sparse]\nthreshold = nan\n",
            "[sparse]\nthreshold = lots\n",
        ] {
            let c = Config::parse(bad).unwrap();
            assert!(c.sparse_settings().is_err(), "{bad:?}");
        }
    }

    #[test]
    fn documented_keys_cover_both_sections() {
        let keys = documented_keys();
        assert!(keys.iter().any(|(s, k, _)| *s == "coordinator" && *k == "workers"));
        assert!(keys.iter().any(|(s, k, _)| *s == "engine" && *k == "max_tile"));
        assert!(keys.iter().any(|(s, k, _)| *s == "plan_cache" && *k == "capacity"));
        // Every key the typed accessors read must be documented.
        for key in ["workers", "queue_depth", "max_batch", "batch_window_ms"] {
            assert!(keys.iter().any(|(s, k, _)| *s == "coordinator" && *k == key), "{key}");
        }
        for key in ["threads", "block", "max_tile"] {
            assert!(keys.iter().any(|(s, k, _)| *s == "engine" && *k == key), "{key}");
        }
        for key in ["threads", "pin", "engine_share", "shard_share", "coordinator_share"] {
            assert!(keys.iter().any(|(s, k, _)| *s == "pool" && *k == key), "{key}");
        }
        for key in [
            "listen",
            "max_body_bytes",
            "max_inflight_per_client",
            "max_connections",
            "read_timeout_ms",
            "submit_wait_ms",
            "drain_timeout_ms",
        ] {
            assert!(keys.iter().any(|(s, k, _)| *s == "server" && *k == key), "{key}");
        }
        assert!(keys.iter().any(|(s, k, d)| *s == "kernels" && *k == "force" && d == "auto"));
        assert!(keys.iter().any(|(s, k, d)| *s == "sparse" && *k == "force" && d == "auto"));
        assert!(keys.iter().any(|(s, k, d)| *s == "sparse" && *k == "threshold" && d == "0.9"));
    }
}
