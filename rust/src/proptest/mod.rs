//! Property-based testing substrate (the offline image has no `proptest`
//! crate; see DESIGN.md §Substitutions).
//!
//! A [`Gen`] wraps the deterministic [`crate::util::Rng`]; [`run_prop`]
//! executes a property across many generated cases and reports the failing
//! seed so any failure is replayable with `TRIADA_PROP_SEED=<seed>`.

use crate::util::Rng;

/// Random-input generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// Case index (0-based), useful for size scaling.
    pub case: usize,
}

impl Gen {
    pub fn new(seed: u64, case: usize) -> Gen {
        Gen { rng: Rng::new(seed), case }
    }

    /// Underlying RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.usize_range(lo, hi)
    }

    /// f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.f64_range(lo, hi)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.usize(xs.len())]
    }

    /// A random cuboid shape with each side in [lo, hi]; occasionally
    /// degenerate (side = lo) to probe edge cases.
    pub fn shape_in(&mut self, lo: usize, hi: usize) -> (usize, usize, usize) {
        (
            self.usize_in(lo, hi),
            self.usize_in(lo, hi),
            self.usize_in(lo, hi),
        )
    }

    /// Random power-of-two in [lo, hi].
    pub fn pow2_in(&mut self, lo: usize, hi: usize) -> usize {
        let mut opts = Vec::new();
        let mut p = 1usize;
        while p <= hi {
            if p >= lo {
                opts.push(p);
            }
            p <<= 1;
        }
        assert!(!opts.is_empty(), "no power of two in [{lo},{hi}]");
        *self.choose(&opts)
    }
}

/// Outcome of a single property case.
pub type PropResult = Result<(), String>;

/// Run `cases` instances of `prop`. Panics with the failing case + seed on
/// the first failure. Base seed comes from `TRIADA_PROP_SEED` if set, so
/// failures are replayable.
pub fn run_prop(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    let base: u64 = std::env::var("TRIADA_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut g = Gen::new(seed, case);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (replay with TRIADA_PROP_SEED={base}): {msg}"
            );
        }
    }
}

/// Assert helper producing `PropResult`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Assert two f64s are within tolerance.
#[macro_export]
macro_rules! prop_assert_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b) = ($a, $b);
        if (a - b).abs() > $tol {
            return Err(format!(
                "{} = {a} differs from {} = {b} by {} (tol {})",
                stringify!($a),
                stringify!($b),
                (a - b).abs(),
                $tol
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_prop("trivial", 25, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_name() {
        run_prop("fails", 10, |g| {
            let v = g.usize_in(0, 100);
            if v < 1000 {
                Err("always".to_string())
            } else {
                let _ = v;
                Ok(())
            }
        });
    }

    #[test]
    fn generators_respect_bounds() {
        run_prop("bounds", 50, |g| {
            let (a, b, c) = g.shape_in(1, 9);
            prop_assert!((1..=9).contains(&a), "a={a}");
            prop_assert!((1..=9).contains(&b), "b={b}");
            prop_assert!((1..=9).contains(&c), "c={c}");
            let p = g.pow2_in(2, 16);
            prop_assert!(p.is_power_of_two() && (2..=16).contains(&p), "p={p}");
            Ok(())
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        run_prop("det1", 5, |g| {
            first.push(g.usize_in(0, 1_000_000));
            Ok(())
        });
        let mut second = Vec::new();
        run_prop("det2", 5, |g| {
            second.push(g.usize_in(0, 1_000_000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
